"""Streaming ingest: append -> prune -> drop on delta-staged device planes.

A streaming workload continuously creates and drops micro-partitions.
Before this feature, ANY DML bumped the table version and forced a full
``[C, P]`` restage of every resident device plane — O(table) staging per
append.  With delta staging the planes are allocated with padded
partition capacity and sync in place: appends stage only the new
``[C, ΔP]`` columns, drops scatter no-op sentinels, and only a rewrite
or capacity overflow pays a full restage.  The staging counters in
``PruningReport.counters["staging"]`` make the difference visible.

Run:  PYTHONPATH=src python examples/streaming_ingest.py
"""

import numpy as np

from repro.core import expr as E
from repro.core.flow import PruningPipeline, Query, TableScanSpec
from repro.data.table import Table
from repro.serve.prune_service import PruningService

rng = np.random.default_rng(0)


def batch(n, t0, span=10_000):
    """One ingest flush: n event rows from a moving time window."""
    return {
        "ts": (t0 + rng.integers(0, span, n)).astype(np.int64),
        "user_id": rng.integers(0, 5_000, n).astype(np.int64),
        "score": rng.integers(0, 1_000, n).astype(np.int64),
    }


# A fact table with 200 initial micro-partitions, clustered by time.
events = Table.build("events", batch(200_000, 0, span=10_000_000),
                     rows_per_partition=1000)
events.update_column("ts", np.sort(events.data["ts"]).astype(np.int64))

svc = PruningService(mode="ref")
pipe = PruningPipeline(filter_mode="device", service=svc)


def recent_window(k=None):
    q = Query(scans={"events": TableScanSpec(
        events, E.col("ts") >= int(events.data["ts"].max()) - 20_000)})
    if k:
        q.limit, q.order_by = k, ("events", "score", True)
    return q


def show(tag, rep):
    f = rep.per_scan["events"]["filter"]
    s = rep.counters["staging"]
    e = rep.counters["planes"]["events"]
    print(f"{tag:>22}: {f.before:4d} -> {f.after:3d} partitions | "
          f"staged {s['staged_bytes']:>9,} B "
          f"(delta={s['delta_stages']}, full={s['full_restages']}) | "
          f"epoch v{e['version']} live={e['live']}/{e['capacity']}")


# -- 1. first batch stages the full [C, cap] planes (once) -----------------
rep = svc.run_batch([recent_window()], pipe)[0]
show("initial staging", rep)

# -- 2. streaming appends: each flush stages only the [C, ΔP] delta --------
t0 = 10_000_000
for i in range(4):
    events.append_partitions(batch(2_000, t0 + i * 10_000),
                             rows_per_partition=1000)
    rep = svc.run_batch([recent_window()], pipe)[0]
    show(f"append +2 partitions", rep)

# -- 3. retention: drop the oldest partitions (sentinel scatter, no reshape)
events.drop_partitions(np.arange(8))
rep = svc.run_batch([recent_window()], pipe)[0]
show("drop 8 oldest", rep)

# -- 4. runtime techniques ride the same delta-synced planes ---------------
rep = svc.run_batch([recent_window(k=10)], pipe)[0]
t = rep.per_scan["events"]["topk"]
show("top-k over deltas", rep)
print(f"{'':>22}  top-k boundary skipped "
      f"{t.before - t.after} of {t.before} partitions "
      f"(path: {t.detail['path']})")

# -- 5. an in-place rewrite is the one op that restages in full ------------
pid = int(np.where(events.live_mask)[0][0])
n = int(np.diff(events.part_bounds)[pid])
events.rewrite_partitions([pid], batch(n, t0))
rep = svc.run_batch([recent_window()], pipe)[0]
show("rewrite 1 partition", rep)

host = PruningPipeline().run(recent_window())
assert np.array_equal(rep.scan_sets["events"].part_ids,
                      host.scan_sets["events"].part_ids)
print(f"{'':>22}  device scan set == host oracle after all DML ✓")
