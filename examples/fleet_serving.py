"""Fleet serving: thousands of tables under an HBM budget.

The paper's pruning numbers assume the min/max metadata is *always hot*
— which, fleet-wide, only works if residency is bounded.  This example
drives a many-table workload with skewed, shifting table popularity
through the budgeted engine and reads the knobs off the counters:

  1. **budget sizing** — stage the fleet once unbounded and read
     ``cache.resident_bytes``: that is the working set.  A budget is a
     fraction of it; the counters tell you whether the fraction holds.
  2. **eviction counters** — ``counters["memory"]`` per batch:
     ``hits / misses`` (plane getter traffic), ``evictions`` (LRU
     pressure), ``restage_storms`` (a previously-evicted plane came
     back: the thrash signal — if it climbs every round, the budget is
     too small for the workload's hot set).
  3. **the invariants** — ``bytes_in_use`` never exceeds the budget
     (``over_budget_events == 0``) because every launch pins its planes
     only while in flight.

On a multi-device host the same engine partition-shards every launch
over the plane mesh (``shard_map``), so one table's planes can outgrow
a single device; outputs are bit-identical either way.

Run:  PYTHONPATH=src python examples/fleet_serving.py
"""

import numpy as np

import jax

from repro.core import expr as E
from repro.core.flow import PruningPipeline, Query, TableScanSpec
from repro.data.table import Table
from repro.serve.prune_service import PruningService

rng = np.random.default_rng(0)

N_TABLES = 48
ROUNDS = 6
QUERIES_PER_ROUND = 64


def build_fleet(n):
    """n small fact tables: same schema, independent data."""
    tables = []
    for i in range(n):
        rows = 240
        tables.append(Table.build(f"events_{i:03d}", {
            "ts": np.sort(rng.integers(0, 100_000, rows)).astype(np.int64),
            "user_id": rng.integers(0, 5_000, rows).astype(np.int64),
            "score": rng.integers(0, 1_000, rows).astype(np.int64),
        }, rows_per_partition=10))
    return tables


def skewed_queries(tables, popularity, n):
    """Zipf-popular tables; filter + top-k mix (tight windows)."""
    qs = []
    for _ in range(n):
        t = tables[int(rng.choice(len(tables), p=popularity))]
        lo = int(rng.integers(0, 90_000))
        if rng.random() < 0.25:
            qs.append(Query(
                scans={t.name: TableScanSpec(t, E.col("ts") >= lo)},
                limit=5, order_by=(t.name, "score", True)))
        else:
            qs.append(Query(scans={t.name: TableScanSpec(
                t, (E.col("ts") >= lo) & (E.col("ts") <= lo + 8_000))}))
    return qs


def zipf(n, s=2.2):
    w = 1.0 / np.arange(1, n + 1) ** s
    return w / w.sum()


tables = build_fleet(N_TABLES)

# -- 1. budget sizing: measure the unbounded working set -------------------
probe = PruningService(mode="ref")
probe_pipe = PruningPipeline(filter_mode="device", service=probe)
probe.run_batch(skewed_queries(tables, np.full(N_TABLES, 1 / N_TABLES),
                               2 * N_TABLES), probe_pipe)
working_set = probe.cache.resident_bytes
budget = int(working_set * 0.25)   # holds the zipf hot set, not the tail
print(f"unbounded working set ~{working_set:,} B -> budget {budget:,} B "
      f"(25%)\n")

# -- 2. the budgeted (and, multi-device, sharded) fleet engine -------------
shard = len(jax.devices()) > 1
svc = PruningService(mode="ref", budget_bytes=budget,
                     shard_mesh=True if shard else None)
pipe = PruningPipeline(filter_mode="device", service=svc)
print(f"devices={len(jax.devices())} sharded={'yes' if shard else 'no'}\n")

popularity = zipf(N_TABLES)
for rnd in range(ROUNDS):
    if rnd == ROUNDS // 2:
        # popularity shifts mid-run: yesterday's cold tables become hot —
        # the LRU follows, at the price of restage storms
        popularity = popularity[::-1].copy()
        print("-- popularity flipped --")
    reports = svc.run_batch(skewed_queries(tables, popularity,
                                           QUERIES_PER_ROUND), pipe)
    m = reports[0].counters["memory"]
    print(f"round {rnd}: hits={m['hits']:4d} misses={m['misses']:3d} "
          f"evictions={m['evictions']:3d} storms={m['restage_storms']:3d} | "
          f"in_use {m['bytes_in_use']:>9,} / {budget:,} B "
          f"(peak {m['peak_bytes']:,})")

# -- 3. the invariants + lifetime summary ----------------------------------
summary = svc.fleet_summary()
mem = summary["memory"]
assert mem["over_budget_events"] == 0, "budget was exceeded"
assert mem["peak_bytes"] <= budget
print(f"\nlifetime: plane hit rate {summary['plane_hit_rate']:.1%}, "
      f"{mem['evictions']} evictions, {mem['restage_storms']} restage "
      f"storms, {summary['counters']['sharded_launches']} sharded launches")
print("budget never exceeded; pinned launches never lost a plane.")
