"""Sublinear batched pruning: the hierarchical tree plane at large P.

The flat batched kernel is one launch for Q queries — but that launch
still scans all P partitions per query, so qps collapses linearly as a
table grows.  PR 7's tree plane family makes the *pruning decision
itself* sublinear, the paper's thesis applied to its own metadata:

  1. **group hulls** — the `[C, P]` min/max/demote plane aggregates
     into `[C, G]` per-group hulls (G = capacity / fanout) plus a tiny
     host-resident coarse root.  A range that misses a group's hull
     provably misses every member partition.
  2. **pay before you launch** — the coarse root is evaluated on the
     host first.  If a predicate keeps more than half the groups, the
     pre-pass cannot win and the engine runs the flat launch directly
     (zero extra launches on dense workloads); otherwise gathered
     evaluations touch only surviving groups' members, so device cost
     scales with survivors, not P.
  3. **same answers** — group pruning only ever *removes* provably-NO
     partitions; FULL is never decided above leaves.  Verdicts are
     bit-identical to the flat path and the f64 host oracle, and the
     tree planes ride the same delta staging, HBM budget, CRC
     integrity protocol, and degradation ladder (rungs
     ``sharded_tree``/``tree`` demote to the flat rungs on any fault).

This walkthrough stages one clustered table at a few sizes and prints
the flat-vs-tree wall time plus the counters that show which path ran.

Run:  PYTHONPATH=src python examples/sublinear_pruning.py
"""

import time

import numpy as np

from repro.core.device_stats import DeviceStats, plane_capacity, tree_entry_for
from repro.core import expr as E
from repro.core.flow import PruningPipeline, Query, TableScanSpec
from repro.core.metadata import ColumnMeta, PartitionStats
from repro.data.table import Table
from repro.kernels import ops
from repro.serve.prune_service import PruningService

rng = np.random.default_rng(0)

Q = 64
SPAN_PARTS = 256          # absolute survivor span per query


def clustered_stats(P):
    """Sorted minima: the natural clustering that makes pruning work."""
    mins = np.sort(rng.uniform(0.0, 1e6, (P, 2)), axis=0)
    maxs = mins + (1e6 / P) * rng.uniform(0.5, 4.0, (P, 2))
    return PartitionStats(
        columns=[ColumnMeta("ts", "float"), ColumnMeta("score", "float")],
        mins=mins, maxs=maxs,
        null_counts=np.zeros((P, 2), dtype=np.int64),
        row_counts=np.full(P, 100, dtype=np.int64))


def narrow_queries(P):
    """Fixed absolute span: survivors stay constant as P grows."""
    width = np.float32(1e6 * SPAN_PARTS / P)
    out = []
    for _ in range(Q):
        lo = np.float32(rng.uniform(0.0, 1e6 - float(width)))
        out.append([(0, float(lo), float(np.float32(lo + width)))])
    return out


def kernel_level():
    print(f"== kernel level: flat vs tree, Q={Q} narrow queries ==")
    for P in (100_000, 1_000_000):
        stats = clustered_stats(P)
        dstats = DeviceStats.stage(stats, capacity=plane_capacity(P))
        tree = tree_entry_for(dstats)
        queries = narrow_queries(P)

        def flat():
            return ops.prune_ranges_batched_device(queries, dstats,
                                                   mode="ref")

        def treed():
            return ops.prune_ranges_batched_tree(queries, dstats, tree,
                                                 mode="ref")

        tv_flat, tv_tree = flat(), treed()        # warm + verify
        np.testing.assert_array_equal(tv_tree, tv_flat)
        t0 = time.perf_counter(); flat(); s_flat = time.perf_counter() - t0
        t0 = time.perf_counter(); treed(); s_tree = time.perf_counter() - t0
        note = ops.last_tree_stats()
        print(f"  P={P:>9,}: flat {s_flat * 1e3:8.1f} ms   "
              f"tree {s_tree * 1e3:7.1f} ms   "
              f"({s_flat / s_tree:6.1f}x, path={note['path']}, "
              f"coarse density {note.get('coarse_density', 0):.3f}) "
              f"- bit-identical")

    # dense workload: the coarse root declines the pre-pass, zero extra
    # launches — the stale-selectivity trap the guard cell pins
    stats = clustered_stats(100_000)
    dstats = DeviceStats.stage(stats, capacity=plane_capacity(100_000))
    tree = tree_entry_for(dstats)
    wide = [[(0, 0.0, 1e6)] for _ in range(Q)]
    ops.prune_ranges_batched_tree(wide, dstats, tree, mode="ref")
    print(f"  dense predicate -> path={ops.last_tree_stats()['path']} "
          "(pre-pass skipped, one flat launch)")


def service_level():
    print("\n== service level: tree rungs in the degradation ladder ==")
    rows = 40_960
    table = Table.build("events", {
        "ts": np.sort(rng.integers(0, 1_000_000, rows)).astype(np.int64),
        "score": rng.integers(0, 1_000, rows).astype(np.int64),
    }, rows_per_partition=10)                     # 4096 partitions
    svc = PruningService(mode="ref", tree_fanout=64)
    pipe = PruningPipeline(filter_mode="device", service=svc)
    lo = 500_000
    qs = [Query(scans={"events": TableScanSpec(
        table, (E.col("ts") >= lo + i) & (E.col("ts") <= lo + i + 5_000))})
        for i in range(16)]
    reports = svc.run_batch(qs, pipe)
    kept = sum(len(r.scan_sets["events"].part_ids) for r in reports)
    c = reports[0].counters
    print(f"  {len(qs)} queries over {table.num_partitions} partitions: "
          f"kept {kept} partition scans total")
    print(f"  launches={c['launches']} tree_launches={c['tree_launches']} "
          f"host_fallbacks={c['host_fallbacks']}")

    # DML: the tree plane delta-replays alongside the flat plane
    table.append_partitions({
        "ts": np.sort(rng.integers(0, 1_000_000, 640)).astype(np.int64),
        "score": rng.integers(0, 1_000, 640).astype(np.int64),
    }, rows_per_partition=10)
    svc.run_batch(qs, pipe)
    snap = svc.cache.staging_snapshot()
    print(f"  after append: delta_stages={snap['delta_stages']} "
          f"full_restages={snap['full_restages']} "
          "(tree groups re-aggregated in place)")


if __name__ == "__main__":
    kernel_level()
    service_level()
