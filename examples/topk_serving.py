"""Serving-side example: batched top-k analytics + LM decode behind one
stack.

Production serving deployments carry an analytics sidecar (request logs,
feature stores) — exactly the workload the paper's top-k pruning (Sec. 5)
accelerates.  This example:
  1. serves batched `ORDER BY score DESC LIMIT k` queries over a logged-
     requests table with boundary-value pruning (vs. the full scan), and
  2. runs a small LM through prefill+decode with the same Generator the
     dry-run's decode shapes lower.

Run:  PYTHONPATH=src python examples/topk_serving.py
"""

import time

import numpy as np

from repro.configs import get_smoke_config
from repro.core import expr as E
from repro.core.flow import PruningPipeline, Query, TableScanSpec
from repro.data.generator import ColumnSpec, gen_table
from repro.data.scan import execute_query
from repro.models import build_model
from repro.models.sharding import init_params
from repro.serve.serve_step import Generator

rng = np.random.default_rng(0)

# ---- 1. the analytics sidecar: top-k over logged requests -----------------
requests = gen_table(
    "requests", rng, n_rows=200_000, rows_per_partition=1000,
    specs=[
        ColumnSpec("ts", "int", 0, 10_000_000, clustering=0.99),
        ColumnSpec("latency_ms", "float", 1.0, 5000.0, clustering=0.35),
        ColumnSpec("model", "str", n_distinct=8, clustering=0.2,
                   str_groups=("lm", "vlm")),
        ColumnSpec("tokens_out", "int", 1, 4096, clustering=0.0),
    ],
)

pipe = PruningPipeline()
queries = [
    ("slowest requests today",
     Query(scans={"requests": TableScanSpec(requests, E.col("ts") >= 9_000_000)},
           limit=20, order_by=("requests", "latency_ms", True))),
    ("top token producers",
     Query(scans={"requests": TableScanSpec(requests)},
           limit=10, order_by=("requests", "tokens_out", True))),
]
for name, q in queries:
    t0 = time.perf_counter()
    rep = pipe.run(q)
    res = execute_query(q, rep)
    dt = (time.perf_counter() - t0) * 1e3
    base = execute_query(q, None)
    t = rep.per_scan["requests"].get("topk")
    skipped = len(rep.topk.skipped) if rep.topk is not None else 0
    print(f"[analytics] {name}: {skipped} of "
          f"{t.before if t else '?'} partitions skipped "
          f"({res.total_bytes()/1e6:.1f} MB vs {base.total_bytes()/1e6:.1f} MB "
          f"unpruned) in {dt:.0f} ms")

# ---- 2. the LM behind it: batched prefill + decode -------------------------
cfg = get_smoke_config("llama3.2-3b")
model = build_model(cfg)
import jax
params = init_params(model.specs, jax.random.PRNGKey(0))
gen = Generator(model, params, max_seq=64)
prompts = np.array([[1, 5, 9, 13, 17, 21, 25, 29]] * 4)  # batch of 4
out = gen.generate(prompts, steps=16)
print(f"[serving] decoded {out.shape} tokens; sample: {out[0][:8].tolist()}")
