"""Resilient serving: fail prune-less, never wrong, never crash.

Pruning's saving grace is that a safe degraded answer always exists —
keeping a partition is always correct, the scan just reads more.  The
resilience layer (PR 6) turns that into a degradation ladder every
batched launch runs through:

    sharded device kernel -> device kernel -> host kernel
        -> host oracle -> no-prune passthrough

Each rung gets bounded retries with exponential backoff and a per-stage
deadline; each demotion lands in ``counters["resilience"]``.  Beneath
the ladder, every staged metadata plane carries a CRC stamp that a
sampled read schedule re-verifies — a torn plane is quarantined and
restaged (a counter), never served as a wrong verdict.

This example injects three escalating failure waves through the
``FaultInjector`` chaos seam and reads the story off the counters:

  1. **transient launch blips** — retries absorb them, no demotion;
  2. **the device path goes dark** — every launch demotes to the host
     kernel; answers stay bit-identical to the oracle;
  3. **torn planes** — staged bytes corrupted in flight; the checksum
     verifier quarantines and restages, verdicts never change.

Run:  PYTHONPATH=src python examples/resilient_serving.py
"""

import numpy as np

from repro.core import expr as E
from repro.core.flow import PruningPipeline, Query, TableScanSpec
from repro.data.table import Table
from repro.serve.prune_service import PruningService
from repro.serve.resilience import BackoffPolicy, FaultInjector

rng = np.random.default_rng(0)

N_TABLES = 8
QUERIES_PER_ROUND = 32


def build_tables(n):
    tables = []
    for i in range(n):
        rows = 240
        tables.append(Table.build(f"events_{i:03d}", {
            "ts": np.sort(rng.integers(0, 100_000, rows)).astype(np.int64),
            "score": rng.integers(0, 1_000, rows).astype(np.int64),
        }, rows_per_partition=10))
    return tables


def queries(tables, n):
    qs = []
    for _ in range(n):
        t = tables[int(rng.integers(0, len(tables)))]
        lo = int(rng.integers(0, 90_000))
        qs.append(Query(scans={t.name: TableScanSpec(
            t, (E.col("ts") >= lo) & (E.col("ts") <= lo + 8_000))}))
    return qs


def kept(report, q):
    (name,) = q.scans
    return set(report.scan_sets[name].part_ids.tolist())


tables = build_tables(N_TABLES)
oracle = PruningPipeline()          # the f64 host reference

injector = FaultInjector(seed=7)
svc = PruningService(mode="ref", fault_injector=injector,
                     backoff=BackoffPolicy(retries=2, base_delay=0.001),
                     integrity_sample=1)    # verify every read (demo; the
                                            # default samples every 64th)
pipe = PruningPipeline(filter_mode="device", service=svc)

def wave3():
    injector.add("stage.stat", kind="corrupt", prob=0.5)
    for t in tables:                 # force restaging so the torn-plane
        svc.cache.invalidate(t.name)  # path actually runs this wave


waves = [
    ("calm: no faults", lambda: None),
    ("wave 1: transient device blips (retries absorb them)",
     lambda: injector.add("launch.filter:device", times=2)),
    ("wave 2: device path dark (ladder demotes to the host kernel)",
     lambda: injector.add("launch.filter:device")),
    ("wave 3: torn planes (checksum quarantines + restages)", wave3),
]

for title, arm in waves:
    injector.clear()
    arm()
    qs = queries(tables, QUERIES_PER_ROUND)
    reports = svc.run_batch(qs, pipe)       # never raises
    res = reports[0].counters["resilience"]   # this batch's delta
    integ = reports[0].counters["integrity"]
    exact = all(kept(r, q) == kept(o, q) for r, q, o in
                zip(reports, qs, (oracle.run(q) for q in qs)))
    demoted = {r: n for r, n in res["demotions"].items() if n}
    print(f"{title}\n"
          f"  retries={res['retries']} demotions={demoted or '{}'} "
          f"passthroughs={res['passthroughs']}\n"
          f"  planes: verified={integ['verifications']} "
          f"torn={integ['checksum_failures']} "
          f"quarantined={integ['quarantines']}\n"
          f"  verdicts vs host oracle: "
          f"{'bit-identical' if exact else 'superset (degraded)'}\n")
    assert exact, "every rung at or above the host oracle is exact"

summary = svc.fleet_summary()
print(f"lifetime: {summary['resilience']['retries']} retries, "
      f"{sum(summary['resilience']['demotions'].values())} demotions, "
      f"{summary['integrity']['quarantines']} quarantines — "
      f"0 wrong verdicts, 0 exceptions reached the caller.")
