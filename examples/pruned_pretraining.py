"""End-to-end driver: pruned data curation feeding LM pre-training.

The paper's engine curates the corpus (filter pruning over shard
metadata), the training loop runs with checkpoint/restart, and the run
reports how much storage I/O pruning avoided.

CPU-scale by default (~20M params, 120 steps, a few minutes):
    PYTHONPATH=src python examples/pruned_pretraining.py
Full-scale (same code path; needs accelerators):
    PYTHONPATH=src python examples/pruned_pretraining.py --steps 500 \
        --batch 32 --seq 512
"""

import sys

from repro.launch.train import main

if __name__ == "__main__":
    argv = sys.argv[1:] or ["--steps", "120", "--batch", "8", "--seq", "128",
                            "--ckpt-dir", "/tmp/repro_quick_ckpt"]
    main(argv)
