"""Finding / baseline engine for the contract linter.

Pure-stdlib (``ast`` + ``tokenize``): linting never imports the checked
code, so the pass runs in CI images without jax and cannot be confused
by import-time side effects.

Data flow::

    paths -> Project (parsed modules + comment maps)
          -> checkers (tools.contract_lint.checkers.ALL_CHECKERS)
          -> [Finding, ...]
          -> Baseline filter (accepted pre-existing findings)
          -> report + exit code

Baseline entries are *line-number independent*: a finding is fingerprinted
by (rule, path, enclosing qualname, stripped source line), so unrelated
edits shifting a file never invalidate the baseline, while editing the
flagged line itself resurfaces the finding for re-review.
"""

from __future__ import annotations

import ast
import dataclasses
import io
import json
import tokenize
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Finding:
    """One contract violation at a source location."""

    rule: str          # stable rule id, e.g. "CL001"
    name: str          # human slug, e.g. "ladder-discipline"
    path: str          # posix relpath of the file
    line: int
    col: int
    message: str
    context: str = ""  # enclosing qualname ("Class.method" / "<module>")
    snippet: str = ""  # stripped source line (baseline matching key)

    @property
    def fingerprint(self) -> Tuple[str, str, str, str]:
        return (self.rule, self.path, self.context, self.snippet)

    def render(self) -> str:
        where = f"{self.path}:{self.line}:{self.col}"
        ctx = f" [{self.context}]" if self.context else ""
        return f"{where}: {self.rule}({self.name}){ctx} {self.message}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


class Baseline:
    """Accepted pre-existing findings, loaded from / saved to JSON.

    Each entry carries the finding fingerprint plus a one-line
    ``justification`` (required — an unexplained suppression is itself a
    contract smell).  One entry suppresses every finding with the same
    fingerprint (identical flagged lines in one scope are one decision).
    """

    def __init__(self, entries: Optional[List[dict]] = None):
        self.entries = entries or []
        self._index = {self._key(e) for e in self.entries}

    @staticmethod
    def _key(entry: dict) -> Tuple[str, str, str, str]:
        return (entry.get("rule", ""), entry.get("path", ""),
                entry.get("context", ""), entry.get("snippet", ""))

    @classmethod
    def load(cls, path) -> "Baseline":
        data = json.loads(Path(path).read_text())
        entries = data["findings"] if isinstance(data, dict) else data
        bad = [e for e in entries if not e.get("justification")]
        if bad:
            raise ValueError(
                f"baseline entries without a justification: "
                f"{[cls._key(e) for e in bad]}")
        return cls(entries)

    def matches(self, finding: Finding) -> bool:
        return finding.fingerprint in self._index

    def split(self, findings: Sequence[Finding]
              ) -> Tuple[List[Finding], List[Finding]]:
        """(new, accepted) partition of ``findings``."""
        new, accepted = [], []
        for f in findings:
            (accepted if self.matches(f) else new).append(f)
        return new, accepted

    def unused(self, findings: Sequence[Finding]) -> List[dict]:
        """Baseline entries no finding matched — stale, should be pruned."""
        hit = {f.fingerprint for f in findings}
        return [e for e in self.entries if self._key(e) not in hit]

    @staticmethod
    def seed(findings: Sequence[Finding],
             justification: str = "FIXME: justify or fix") -> List[dict]:
        out, seen = [], set()
        for f in findings:
            if f.fingerprint in seen:
                continue
            seen.add(f.fingerprint)
            out.append(dict(rule=f.rule, path=f.path, context=f.context,
                            snippet=f.snippet, justification=justification))
        return out


@dataclasses.dataclass
class ModuleInfo:
    """One parsed source file plus the comment map checkers consume."""

    path: str                       # posix relpath
    source: str
    tree: ast.Module
    lines: List[str]
    comments: Dict[int, str]        # line number -> comment text ("# ...")

    def line(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""


def _comment_map(source: str) -> Dict[int, str]:
    out: Dict[int, str] = {}
    try:
        for tok in tokenize.generate_tokens(io.StringIO(source).readline):
            if tok.type == tokenize.COMMENT:
                out[tok.start[0]] = tok.string
    except tokenize.TokenError:     # pragma: no cover - truncated source
        pass
    return out


class Project:
    """The parsed file set one lint run operates on."""

    def __init__(self, modules: List[ModuleInfo]):
        self.modules = modules

    @classmethod
    def from_sources(cls, sources: Dict[str, str]) -> "Project":
        mods = []
        for path, src in sorted(sources.items()):
            posix = Path(path).as_posix()
            tree = ast.parse(src, filename=posix)
            mods.append(ModuleInfo(posix, src, tree, src.splitlines(),
                                   _comment_map(src)))
        return cls(mods)

    def by_suffix(self, suffix: str) -> List[ModuleInfo]:
        return [m for m in self.modules if m.path.endswith(suffix)]


# ---------------------------------------------------------------------------
# shared AST helpers (used by every checker)
# ---------------------------------------------------------------------------

def dotted_name(node: ast.AST) -> Optional[str]:
    """'a.b.c' for Name/Attribute chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def qualnames(tree: ast.Module) -> Dict[ast.AST, str]:
    """Map every function/class node to its dotted qualname."""
    out: Dict[ast.AST, str] = {}

    def walk(node: ast.AST, prefix: str) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                q = f"{prefix}.{child.name}" if prefix else child.name
                out[child] = q
                walk(child, q)
            else:
                walk(child, prefix)

    walk(tree, "")
    return out


def enclosing_scopes(tree: ast.Module) -> Dict[ast.AST, List[ast.AST]]:
    """Map every node to its stack of enclosing function/class defs."""
    out: Dict[ast.AST, List[ast.AST]] = {}

    def walk(node: ast.AST, stack: List[ast.AST]) -> None:
        for child in ast.iter_child_nodes(node):
            out[child] = stack
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                walk(child, stack + [child])
            else:
                walk(child, stack)

    walk(tree, [])
    return out


def string_elements(node: ast.AST) -> List[str]:
    """String literals inside a tuple/list/set/frozenset(...) literal."""
    if isinstance(node, ast.Call) and dotted_name(node.func) in (
            "frozenset", "set", "tuple", "list"):
        if node.args:
            return string_elements(node.args[0])
        return []
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        return [e.value for e in node.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, str)]
    return []


def collect_registry(project: Project, var_name: str) -> Optional[set]:
    """Union of string elements of every module-level ``var_name = {...}``
    assignment across the project; None when no module declares it."""
    found = None
    for mod in project.modules:
        for node in mod.tree.body:
            targets = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                targets = [node.target]
            else:
                continue
            for t in targets:
                if isinstance(t, ast.Name) and t.id == var_name:
                    found = (found or set())
                    found.update(string_elements(node.value))
    return found


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------

def _iter_py_files(paths: Iterable[str]) -> List[Path]:
    files: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            files.extend(sorted(path.rglob("*.py")))
        elif path.suffix == ".py":
            files.append(path)
    # de-dup while keeping order
    seen, out = set(), []
    for f in files:
        if f not in seen:
            seen.add(f)
            out.append(f)
    return out


@dataclasses.dataclass
class LintConfig:
    select: Optional[Sequence[str]] = None     # rule ids/names to run
    root: Optional[Path] = None                # relpath anchor (default cwd)


def lint_sources(sources: Dict[str, str],
                 config: Optional[LintConfig] = None) -> List[Finding]:
    """Lint in-memory sources ({relpath: source}) — the test entry point."""
    from .checkers import ALL_CHECKERS
    config = config or LintConfig()
    project = Project.from_sources(sources)
    findings: List[Finding] = []
    for checker in ALL_CHECKERS:
        if config.select and checker.rule not in config.select \
                and checker.name not in config.select:
            continue
        findings.extend(checker.run(project))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


def lint_paths(paths: Sequence[str],
               config: Optional[LintConfig] = None) -> List[Finding]:
    config = config or LintConfig()
    root = config.root or Path.cwd()
    sources: Dict[str, str] = {}
    for f in _iter_py_files(paths):
        try:
            rel = f.resolve().relative_to(Path(root).resolve()).as_posix()
        except ValueError:
            rel = f.as_posix()
        sources[rel] = f.read_text()
    return lint_sources(sources, config)
