"""Contract linter: machine-checked enforcement of the repo's contracts.

The engine's correctness rests on cross-cutting contracts that unit
tests cannot see being *bypassed*:

  * the PR 6 degradation contract — every batched launch enters through
    ``DegradationLadder.execute`` and every plane family joins the
    integrity protocol;
  * the precision contract — every f64 -> f32 downcast of stats or query
    bounds goes through the centralized widening helpers in
    ``core/device_stats.py``;
  * the lock discipline — ``DeviceStatsCache`` state is only touched
    under ``self._lock``;
  * trace safety — no host control flow on traced values, no
    nondeterminism inside Pallas kernel bodies or jitted functions;
  * counter registration — every counter key the service emits is
    declared in one registry so ``fleet_summary()`` can never silently
    drop a family.

This package is a pure-``ast`` static-analysis pass (no jax import, no
runtime import of the checked code) with a finding/baseline engine and a
CLI::

    python -m tools.contract_lint src/ --baseline tools/contract_lint/baseline.json

See ``docs/CONTRACTS.md`` for the rule catalogue and
``tools/contract_lint/README.md`` for invocation details.
"""

from .engine import (Baseline, Finding, LintConfig, lint_paths,  # noqa: F401
                     lint_sources)
from .checkers import ALL_CHECKERS  # noqa: F401
