"""The six repo-specific contract checkers.

Each checker is a small class with ``rule`` (stable id), ``name`` (slug)
and ``run(project) -> [Finding]``.  All analysis is purely syntactic
(``ast``) plus the comment map — nothing here imports the checked code.

Rule catalogue (see docs/CONTRACTS.md for the long form):

  CL001 ladder-discipline   batched kernel entrypoints may only be called
                            from registered DegradationLadder launch sites
  CL002 integrity-protocol  plane getters must stamp plane_checksum and
                            account bytes through PlaneMemoryManager
  CL003 lock-discipline     fields annotated ``# guarded-by: _lock`` are
                            only touched under ``with self._lock``
  CL004 precision-contract  raw float32 casts in core/ and kernels/ must
                            go through the centralized widening helpers
  CL005 trace-safety        no host control flow on traced values, no
                            nondeterminism in kernel bodies / jitted fns
  CL006 counter-registration every counter key written by the service is
                            declared in COUNTER_REGISTRY
"""

from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set, Tuple

from .engine import (Finding, ModuleInfo, Project, collect_registry,
                     dotted_name, enclosing_scopes, qualnames)

FUNC_DEFS = (ast.FunctionDef, ast.AsyncFunctionDef)


def _finding(checker, mod: ModuleInfo, node: ast.AST, message: str,
             context: str = "") -> Finding:
    line = getattr(node, "lineno", 0)
    col = getattr(node, "col_offset", 0)
    return Finding(rule=checker.rule, name=checker.name, path=mod.path,
                   line=line, col=col, message=message, context=context,
                   snippet=mod.line(line))


def _context_of(scopes, quals, node) -> str:
    for s in reversed(scopes.get(node, [])):
        q = quals.get(s)
        if q:
            return q
    return "<module>"


# ---------------------------------------------------------------------------
# CL001 · ladder discipline
# ---------------------------------------------------------------------------

class LadderDisciplineChecker:
    """Batched kernel entrypoints (``*_batched*``) reached from serving
    code must be wrapped in a rung list executed by
    ``DegradationLadder.execute``.  Statically we enforce the registry
    form of that contract: every call site must be lexically inside a
    function listed in ``LADDER_LAUNCH_SITES`` (serve/prune_service.py),
    whose entries are by construction rung builders handed to
    ``self.ladder.execute``."""

    rule = "CL001"
    name = "ladder-discipline"

    SCOPE = ("repro/serve/",)
    SCOPE_FILES = ("repro/core/flow.py",)
    REGISTRY = "LADDER_LAUNCH_SITES"

    def _in_scope(self, path: str) -> bool:
        return any(s in path for s in self.SCOPE) or \
            any(path.endswith(f) for f in self.SCOPE_FILES)

    def run(self, project: Project) -> List[Finding]:
        registry = collect_registry(project, self.REGISTRY) or set()
        findings: List[Finding] = []
        for mod in project.modules:
            if not self._in_scope(mod.path):
                continue
            quals = qualnames(mod.tree)
            scopes = enclosing_scopes(mod.tree)
            for node in ast.walk(mod.tree):
                if not isinstance(node, ast.Call):
                    continue
                callee = dotted_name(node.func)
                if callee is None or "_batched" not in callee.split(".")[-1]:
                    continue
                stack = scopes.get(node, [])
                allowed = any(quals.get(s) in registry
                              for s in stack if isinstance(s, FUNC_DEFS))
                if allowed:
                    continue
                ctx = _context_of(scopes, quals, node)
                findings.append(_finding(
                    self, mod, node,
                    f"direct call to batched kernel entrypoint '{callee}' "
                    f"outside a registered DegradationLadder launch site "
                    f"(add the enclosing method to {self.REGISTRY} only if "
                    f"it builds rungs for DegradationLadder.execute)",
                    ctx))
        return findings


# ---------------------------------------------------------------------------
# CL002 · integrity protocol
# ---------------------------------------------------------------------------

class IntegrityProtocolChecker:
    """Every plane family and every plane getter in device_stats.py joins
    the integrity protocol: the ``self._stores`` family map must match the
    ``PLANE_FAMILIES`` registry, and each getter (``get`` / ``*_plane``)
    must transitively reach a ``plane_checksum`` stamp and a
    ``PlaneMemoryManager`` byte-accounting call."""

    rule = "CL002"
    name = "integrity-protocol"

    FILE_SUFFIX = "device_stats.py"
    REGISTRY = "PLANE_FAMILIES"
    CACHE_CLASS = "DeviceStatsCache"

    def run(self, project: Project) -> List[Finding]:
        findings: List[Finding] = []
        for mod in project.modules:
            if not mod.path.endswith(self.FILE_SUFFIX):
                continue
            cls = next((n for n in ast.walk(mod.tree)
                        if isinstance(n, ast.ClassDef)
                        and n.name == self.CACHE_CLASS), None)
            if cls is None:
                continue
            findings.extend(self._check_families(mod, cls))
            findings.extend(self._check_getters(mod, cls))
        return findings

    # -- family registry parity ------------------------------------------

    def _check_families(self, mod: ModuleInfo,
                        cls: ast.ClassDef) -> List[Finding]:
        registry = collect_registry(Project([mod]), self.REGISTRY)
        stores_node, store_keys = None, None
        for node in ast.walk(cls):
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                tgt = dotted_name(node.targets[0])
                if tgt == "self._stores" and isinstance(node.value, ast.Dict):
                    stores_node = node
                    store_keys = {k.value for k in node.value.keys
                                  if isinstance(k, ast.Constant)
                                  and isinstance(k.value, str)}
        out: List[Finding] = []
        if registry is None:
            out.append(_finding(
                self, mod, cls,
                f"module does not declare the {self.REGISTRY} plane-family "
                f"registry the integrity protocol is keyed on",
                self.CACHE_CLASS))
            return out
        if stores_node is None or store_keys is None:
            return out
        ctx = f"{self.CACHE_CLASS}.__init__"
        for fam in sorted(store_keys - registry):
            out.append(_finding(
                self, mod, stores_node,
                f"plane family '{fam}' in self._stores is not declared in "
                f"{self.REGISTRY} — new families MUST join the integrity "
                f"protocol (ROADMAP degradation contract)", ctx))
        for fam in sorted(registry - store_keys):
            out.append(_finding(
                self, mod, stores_node,
                f"{self.REGISTRY} declares family '{fam}' but self._stores "
                f"has no such store", ctx))
        return out

    # -- getter protocol coverage ----------------------------------------

    def _check_getters(self, mod: ModuleInfo,
                       cls: ast.ClassDef) -> List[Finding]:
        methods = {n.name: n for n in cls.body if isinstance(n, FUNC_DEFS)}
        module_funcs = {n.name for n in mod.tree.body
                        if isinstance(n, FUNC_DEFS)}

        def calls_in(fn: ast.AST) -> Set[str]:
            out = set()
            for node in ast.walk(fn):
                if isinstance(node, ast.Call):
                    d = dotted_name(node.func)
                    if d:
                        out.add(d)
            return out

        def reachable_calls(fn_name: str) -> Set[str]:
            seen_fns: Set[str] = set()
            calls: Set[str] = set()
            stack = [fn_name]
            while stack:
                cur = stack.pop()
                if cur in seen_fns or cur not in methods:
                    continue
                seen_fns.add(cur)
                for d in calls_in(methods[cur]):
                    calls.add(d)
                    if d.startswith("self."):
                        stack.append(d.split(".")[1])
                    elif "." not in d and d in module_funcs:
                        # module-level helper: include its calls directly
                        helper = next(n for n in mod.tree.body
                                      if isinstance(n, FUNC_DEFS)
                                      and n.name == d)
                        calls.update(calls_in(helper))
            return calls

        out: List[Finding] = []
        for name, fn in sorted(methods.items()):
            if not (name == "get" or name.endswith("_plane")):
                continue
            calls = reachable_calls(name)
            stamps = any(d.split(".")[-1] == "plane_checksum" for d in calls)
            accounts = any(
                d in ("self._admit", "self._touch")
                or (("memory." in d or d.startswith("memory."))
                    and d.split(".")[-1] in ("admit", "touch"))
                for d in calls)
            ctx = f"{self.CACHE_CLASS}.{name}"
            if not stamps:
                out.append(_finding(
                    self, mod, fn,
                    f"plane getter '{name}' never reaches a plane_checksum "
                    f"stamp — staged planes must carry an integrity "
                    f"checksum", ctx))
            if not accounts:
                out.append(_finding(
                    self, mod, fn,
                    f"plane getter '{name}' never accounts bytes through "
                    f"PlaneMemoryManager (self._admit/self._touch)", ctx))
        return out


# ---------------------------------------------------------------------------
# CL003 · lock discipline
# ---------------------------------------------------------------------------

class LockDisciplineChecker:
    """Fields annotated ``# guarded-by: _lock`` on their ``self.X = ...``
    declaration may only be read or written (a) lexically inside a
    ``with self._lock`` block — including functions *defined* inside one,
    which covers the staging closures — or (b) in a private method whose
    in-class call sites are all themselves lock-safe (computed to a fixed
    point).  ``__init__`` is exempt: the object is not shared yet."""

    rule = "CL003"
    name = "lock-discipline"

    ANNOTATION = re.compile(r"guarded-by:\s*_lock")
    LOCK_EXPR = "self._lock"

    def run(self, project: Project) -> List[Finding]:
        findings: List[Finding] = []
        for mod in project.modules:
            if "guarded-by" not in mod.source:
                continue
            for cls in ast.walk(mod.tree):
                if isinstance(cls, ast.ClassDef):
                    findings.extend(self._check_class(mod, cls))
        return findings

    def _guarded_fields(self, mod: ModuleInfo, cls: ast.ClassDef) -> Set[str]:
        guarded: Set[str] = set()
        for node in ast.walk(cls):
            targets: List[ast.AST] = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
                targets = [node.target]
            for t in targets:
                if isinstance(t, ast.Attribute) and \
                        isinstance(t.value, ast.Name) and t.value.id == "self":
                    for ln in (t.lineno, t.lineno - 1):
                        comment = mod.comments.get(ln, "")
                        if self.ANNOTATION.search(comment):
                            guarded.add(t.attr)
        return guarded

    def _is_lock_with(self, node: ast.With) -> bool:
        return any(dotted_name(item.context_expr) == self.LOCK_EXPR
                   for item in node.items)

    def _check_class(self, mod: ModuleInfo, cls: ast.ClassDef) -> List[Finding]:
        guarded = self._guarded_fields(mod, cls)
        if not guarded:
            return []

        methods = [n for n in cls.body if isinstance(n, FUNC_DEFS)]
        # accesses[m] -> [(node, field, inlock)], callsites[callee] -> [(m, inlock)]
        accesses: Dict[str, List[Tuple[ast.AST, str, bool]]] = {}
        callsites: Dict[str, List[Tuple[str, bool]]] = {}

        def visit(node: ast.AST, method: str, inlock: bool) -> None:
            if isinstance(node, ast.With) and self._is_lock_with(node):
                inlock = True
            if isinstance(node, ast.Attribute) and \
                    isinstance(node.value, ast.Name) and \
                    node.value.id == "self" and node.attr in guarded:
                accesses.setdefault(method, []).append(
                    (node, node.attr, inlock))
            if isinstance(node, ast.Call):
                d = dotted_name(node.func)
                if d and d.startswith("self.") and d.count(".") == 1:
                    callsites.setdefault(d.split(".")[1], []).append(
                        (method, inlock))
            for child in ast.iter_child_nodes(node):
                # functions defined inside a locked region run under it
                visit(child, method, inlock)

        for m in methods:
            if m.name == "__init__":
                continue
            for child in ast.iter_child_nodes(m):
                visit(child, m.name, False)

        # fixed point: private methods whose every in-class call site is
        # lock-safe are themselves lock-safe
        locked_only = {m.name for m in methods
                       if m.name.startswith("_") and callsites.get(m.name)}
        changed = True
        while changed:
            changed = False
            for name in sorted(locked_only):
                for caller, inlock in callsites.get(name, []):
                    if not inlock and caller not in locked_only:
                        locked_only.discard(name)
                        changed = True
                        break

        out: List[Finding] = []
        for method, recs in sorted(accesses.items()):
            if method in locked_only:
                continue
            for node, field, inlock in recs:
                if inlock:
                    continue
                out.append(_finding(
                    self, mod, node,
                    f"field '{field}' is declared guarded-by _lock but is "
                    f"accessed outside a `with {self.LOCK_EXPR}` scope "
                    f"(method '{method}' is reachable without the lock)",
                    f"{cls.name}.{method}"))
        return out


# ---------------------------------------------------------------------------
# CL004 · precision contract
# ---------------------------------------------------------------------------

class PrecisionContractChecker:
    """f64 -> f32 narrowing of stats or bounds must go through the
    centralized widening helpers (``round_down_f32`` / ``round_up_f32`` /
    ``cast_stats_f32`` in core/device_stats.py), which guarantee the
    paper's never-prune-a-match direction.  Raw ``.astype(float32)`` and
    ``float32(...)`` calls elsewhere in core/ and kernels/ are errors.
    Exact casts of boolean masks (comparisons, logical ops) are allowed
    structurally; constants like ``np.float32(-np.inf)`` are exact."""

    rule = "CL004"
    name = "precision-contract"

    SCOPE = ("repro/core/", "repro/kernels/")
    # the widening-helper home itself, and the model-side attention kernel
    # (activations, not stats metadata — out of the contract's domain)
    EXEMPT_SUFFIXES = ("core/device_stats.py", "kernels/flash_attention.py")

    F32 = ("np.float32", "jnp.float32", "numpy.float32", "jax.numpy.float32")
    WIDENING = ("round_down_f32", "round_up_f32", "cast_stats_f32",
                "cast_bounds_f32")

    def _bool_expr(self, n: ast.AST) -> bool:
        if isinstance(n, (ast.Compare, ast.BoolOp)):
            return True
        if isinstance(n, ast.BinOp):
            return self._bool_expr(n.left) or self._bool_expr(n.right)
        if isinstance(n, ast.UnaryOp):
            return self._bool_expr(n.operand)
        if isinstance(n, ast.Call):
            d = (dotted_name(n.func) or "").split(".")[-1]
            return d in ("logical_and", "logical_or", "logical_not",
                         "logical_xor", "isnan", "isinf", "isfinite",
                         "isclose", "equal", "not_equal") \
                or d in self.WIDENING
        return False

    def _const_like(self, n: ast.AST) -> bool:
        if isinstance(n, ast.Constant):
            return True
        if isinstance(n, ast.UnaryOp):
            return self._const_like(n.operand)
        d = dotted_name(n)
        if d is not None and d.split(".")[-1] in ("inf", "nan", "e", "pi"):
            return True
        return False

    def run(self, project: Project) -> List[Finding]:
        findings: List[Finding] = []
        for mod in project.modules:
            if not any(s in mod.path for s in self.SCOPE):
                continue
            if any(mod.path.endswith(s) for s in self.EXEMPT_SUFFIXES):
                continue
            quals = qualnames(mod.tree)
            scopes = enclosing_scopes(mod.tree)
            for node in ast.walk(mod.tree):
                if not isinstance(node, ast.Call):
                    continue
                hit = self._flag(node)
                if hit is None:
                    continue
                ctx = _context_of(scopes, quals, node)
                findings.append(_finding(
                    self, mod, node,
                    f"raw float32 cast ({hit}) outside the centralized "
                    f"widening helpers — use round_down_f32 / round_up_f32 "
                    f"/ cast_stats_f32 from core.device_stats so the "
                    f"narrowing direction is explicit", ctx))
        return findings

    def _flag(self, node: ast.Call) -> Optional[str]:
        func = node.func
        # X.astype(float32)
        if isinstance(func, ast.Attribute) and func.attr == "astype" \
                and len(node.args) == 1:
            arg = node.args[0]
            d = dotted_name(arg)
            is_f32 = (d in self.F32) or (
                isinstance(arg, ast.Constant) and arg.value == "float32")
            if is_f32 and not self._bool_expr(func.value):
                return ".astype(float32)"
            return None
        # float32(X) with non-constant X
        d = dotted_name(func)
        if d in self.F32 and node.args and \
                not all(self._const_like(a) for a in node.args):
            return f"{d}(...)"
        return None


# ---------------------------------------------------------------------------
# CL005 · trace safety
# ---------------------------------------------------------------------------

class TraceSafetyChecker:
    """Inside Pallas kernel bodies and jitted functions: no Python
    ``if``/``while`` on traced parameters (static_argnames are exempt),
    no ``float()``/``int()``/``bool()`` on traced values, no ``.item()``,
    and no nondeterminism (``time.*``, unseeded ``np.random.*``).

    Traced functions are found syntactically: defs whose name ends in
    ``_kernel``, defs passed (by name) as the first argument of
    ``pl.pallas_call`` or wrapped by ``jax.jit(...)`` /
    ``jax.jit(shard_map(...))``, and defs decorated with ``jax.jit`` or
    ``functools.partial(jax.jit, static_argnames=...)``."""

    rule = "CL005"
    name = "trace-safety"

    SCOPE = ("repro/",)
    JIT = ("jax.jit", "jit")
    PARTIAL = ("functools.partial", "partial")
    SHARD = ("shard_map", "jax.experimental.shard_map.shard_map")
    NONDET_PREFIXES = ("time.", "np.random.", "numpy.random.", "random.")
    NONDET_ALLOWED = ("default_rng", "Generator", "SeedSequence", "PRNGKey")

    # -- traced-function discovery ---------------------------------------

    def _static_names(self, call: ast.Call,
                      params: List[str]) -> Set[str]:
        out: Set[str] = set()
        for kw in call.keywords:
            if kw.arg == "static_argnames":
                if isinstance(kw.value, ast.Constant) and \
                        isinstance(kw.value.value, str):
                    out.add(kw.value.value)
                for e in ast.walk(kw.value):
                    if isinstance(e, ast.Constant) and \
                            isinstance(e.value, str):
                        out.add(e.value)
            elif kw.arg == "static_argnums":
                for e in ast.walk(kw.value):
                    if isinstance(e, ast.Constant) and \
                            isinstance(e.value, int) and \
                            0 <= e.value < len(params):
                        out.add(params[e.value])
        return out

    def _jit_decorator(self, dec: ast.AST) -> Optional[ast.Call]:
        """Return the jit Call carrying static_argnames, a bare marker
        Call for plain @jax.jit, or None."""
        if dotted_name(dec) in self.JIT:
            return ast.Call(func=dec, args=[], keywords=[])
        if isinstance(dec, ast.Call):
            d = dotted_name(dec.func)
            if d in self.JIT:
                return dec
            if d in self.PARTIAL and dec.args and \
                    dotted_name(dec.args[0]) in self.JIT:
                return dec
        return None

    def _collect_traced(self, mod: ModuleInfo
                        ) -> List[Tuple[ast.AST, Set[str]]]:
        defs: Dict[str, List[ast.AST]] = {}
        for node in ast.walk(mod.tree):
            if isinstance(node, FUNC_DEFS):
                defs.setdefault(node.name, []).append(node)

        traced: Dict[ast.AST, Set[str]] = {}

        def mark(fn: ast.AST, statics: Set[str]) -> None:
            traced.setdefault(fn, set()).update(statics)

        def mark_name(name: Optional[str], statics: Set[str]) -> None:
            for fn in defs.get(name or "", []):
                mark(fn, statics)

        def kernel_statics(fn: ast.AST) -> Set[str]:
            # Pallas kernel bodies take Refs positionally; keyword-only
            # params are compile-time config bound via functools.partial.
            return {a.arg for a in fn.args.kwonlyargs}

        for name, fns in defs.items():
            for fn in fns:
                if name.endswith("_kernel"):
                    mark(fn, kernel_statics(fn))
                params = [a.arg for a in fn.args.args]
                for dec in fn.decorator_list:
                    jit = self._jit_decorator(dec)
                    if jit is not None:
                        mark(fn, self._static_names(jit, params))

        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            d = dotted_name(node.func)
            last = (d or "").split(".")[-1]
            if last == "pallas_call" and node.args:
                target = dotted_name(node.args[0])
                for fn in defs.get(target or "", []):
                    mark(fn, kernel_statics(fn))
            elif d in self.JIT and node.args:
                inner = node.args[0]
                target = dotted_name(inner)
                if target is None and isinstance(inner, ast.Call) and \
                        dotted_name(inner.func) in self.SHARD and inner.args:
                    target = dotted_name(inner.args[0])
                if target is not None:
                    statics: Set[str] = set()
                    for fn in defs.get(target, []):
                        params = [a.arg for a in fn.args.args]
                        statics = self._static_names(node, params)
                    mark_name(target, statics)
        return list(traced.items())

    # -- per-function checks ---------------------------------------------

    def _roots(self, expr: ast.AST) -> Set[str]:
        """Root Name ids an expression reads, excluding reads through
        shape/dtype-like attributes (those are static under trace)."""
        out: Set[str] = set()
        for node in ast.walk(expr):
            if isinstance(node, ast.Name) and \
                    isinstance(node.ctx, ast.Load):
                out.add(node.id)
        # drop roots only reached through static attrs
        for node in ast.walk(expr):
            if isinstance(node, ast.Attribute) and \
                    node.attr in ("shape", "ndim", "size", "dtype"):
                for sub in ast.walk(node.value):
                    if isinstance(sub, ast.Name):
                        out.discard(sub.id)
        return out

    def _is_none_check(self, test: ast.AST) -> bool:
        return (isinstance(test, ast.Compare)
                and all(isinstance(op, (ast.Is, ast.IsNot))
                        for op in test.ops)
                and all(isinstance(c, ast.Constant) and c.value is None
                        for c in test.comparators))

    def run(self, project: Project) -> List[Finding]:
        findings: List[Finding] = []
        for mod in project.modules:
            if not any(s in mod.path for s in self.SCOPE):
                continue
            quals = qualnames(mod.tree)
            for fn, statics in self._collect_traced(mod):
                findings.extend(
                    self._check_fn(mod, fn, statics, quals.get(fn, fn.name)))
        findings.sort(key=lambda f: (f.path, f.line, f.col))
        # a def can be discovered twice (name + pallas_call ref): dedup
        seen, out = set(), []
        for f in findings:
            key = (f.path, f.line, f.col, f.message)
            if key not in seen:
                seen.add(key)
                out.append(f)
        return out

    def _check_fn(self, mod: ModuleInfo, fn: ast.AST, statics: Set[str],
                  ctx: str) -> List[Finding]:
        params = {a.arg for a in fn.args.args} | \
            {a.arg for a in fn.args.kwonlyargs}
        traced_params = params - statics - {"self"}
        out: List[Finding] = []
        for node in ast.walk(fn):
            if isinstance(node, (ast.If, ast.While)) and \
                    not self._is_none_check(node.test):
                hot = self._roots(node.test) & traced_params
                if hot:
                    kind = "if" if isinstance(node, ast.If) else "while"
                    out.append(_finding(
                        self, mod, node,
                        f"Python `{kind}` on traced value(s) "
                        f"{sorted(hot)} inside a traced function — use "
                        f"jnp.where/lax.cond or declare the argument in "
                        f"static_argnames", ctx))
            if isinstance(node, ast.Call):
                d = dotted_name(node.func)
                if d in ("float", "int", "bool") and node.args:
                    hot = self._roots(node.args[0]) & traced_params
                    if hot:
                        out.append(_finding(
                            self, mod, node,
                            f"`{d}()` forces a concrete value from traced "
                            f"value(s) {sorted(hot)} — this fails under "
                            f"jit; keep it an array op", ctx))
                if isinstance(node.func, ast.Attribute) and \
                        node.func.attr == "item" and not node.args:
                    out.append(_finding(
                        self, mod, node,
                        "`.item()` inside a traced function forces a "
                        "device sync / concretization error under jit",
                        ctx))
                if d is not None:
                    for prefix in self.NONDET_PREFIXES:
                        if d.startswith(prefix) and \
                                d.split(".")[-1] not in self.NONDET_ALLOWED:
                            out.append(_finding(
                                self, mod, node,
                                f"nondeterministic call '{d}' inside a "
                                f"traced function — results get baked in "
                                f"at trace time and break retrace "
                                f"reproducibility", ctx))
                            break
        return out


# ---------------------------------------------------------------------------
# CL006 · counter registration
# ---------------------------------------------------------------------------

class CounterRegistrationChecker:
    """Every string key written into a counter store (``*.counters``,
    ``*.resilience``, ``*.integrity``, ``*.technique``), every key of a
    ``new_*_counters()`` definition dict, and every literal technique name
    passed to ``bump(...)`` must be declared in ``COUNTER_REGISTRY``
    (serve/resilience.py), so fleet_summary() can never silently drop a
    counter family."""

    rule = "CL006"
    name = "counter-registration"

    SCOPE = ("repro/serve/",)
    SCOPE_FILES = ("device_stats.py",)
    REGISTRY = "COUNTER_REGISTRY"
    COUNTER_ATTRS = ("counters", "resilience", "integrity", "technique")

    def _in_scope(self, path: str) -> bool:
        return any(s in path for s in self.SCOPE) or \
            any(path.endswith(f) for f in self.SCOPE_FILES)

    def _is_counter_expr(self, node: ast.AST, aliases: Set[str]) -> bool:
        if isinstance(node, ast.Name):
            return node.id in aliases or node.id in self.COUNTER_ATTRS
        d = dotted_name(node)
        return d is not None and d.split(".")[-1] in self.COUNTER_ATTRS

    def run(self, project: Project) -> List[Finding]:
        registry = collect_registry(project, self.REGISTRY)
        findings: List[Finding] = []
        for mod in project.modules:
            if not self._in_scope(mod.path):
                continue
            quals = qualnames(mod.tree)
            scopes = enclosing_scopes(mod.tree)
            for fn_node, keys in self._collect_keys(mod):
                for node, key in keys:
                    if registry is not None and key in registry:
                        continue
                    where = "" if registry is not None else \
                        " (registry not found in the linted tree)"
                    ctx = _context_of(scopes, quals, node)
                    findings.append(_finding(
                        self, mod, node,
                        f"counter key '{key}' is not declared in "
                        f"{self.REGISTRY} (serve/resilience.py){where} — "
                        f"unregistered keys silently vanish from "
                        f"fleet_summary()", ctx))
        return findings

    def _collect_keys(self, mod: ModuleInfo):
        """Yield (scope_node, [(node, key), ...]) per function/module."""
        results = []

        def handle_scope(scope: ast.AST) -> None:
            aliases: Set[str] = set()
            keys: List[Tuple[ast.AST, str]] = []

            def dict_keys(value: ast.AST) -> List[Tuple[ast.AST, str]]:
                out = []
                if isinstance(value, ast.Dict):
                    for k in value.keys:
                        if isinstance(k, ast.Constant) and \
                                isinstance(k.value, str):
                            out.append((k, k.value))
                elif isinstance(value, ast.Call) and \
                        dotted_name(value.func) == "dict":
                    for kw in value.keywords:
                        if kw.arg is not None:
                            out.append((value, kw.arg))
                return out

            def visit(node: ast.AST) -> None:
                if isinstance(node, FUNC_DEFS) and node is not scope:
                    handle_scope(node)
                    return
                if isinstance(node, ast.Assign):
                    # alias: c = self.counters / t = x.technique.setdefault(..)
                    rhs = node.value
                    rhs_counter = self._is_counter_expr(rhs, aliases) or (
                        isinstance(rhs, ast.Call)
                        and isinstance(rhs.func, ast.Attribute)
                        and rhs.func.attr == "setdefault"
                        and self._is_counter_expr(rhs.func.value, aliases))
                    for t in node.targets:
                        if rhs_counter and isinstance(t, ast.Name):
                            aliases.add(t.id)
                        # counter definition dict: x.counters = {...}
                        if isinstance(t, ast.Attribute) and \
                                t.attr in self.COUNTER_ATTRS:
                            keys.extend(dict_keys(rhs))
                targets: List[ast.AST] = []
                if isinstance(node, ast.Assign):
                    targets = list(node.targets)
                elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                    targets = [node.target]
                for t in targets:
                    if isinstance(t, ast.Subscript) and \
                            self._is_counter_expr(t.value, aliases) and \
                            isinstance(t.slice, ast.Constant) and \
                            isinstance(t.slice.value, str):
                        keys.append((t, t.slice.value))
                if isinstance(node, ast.Call):
                    d_attr = node.func if isinstance(node.func, ast.Attribute) \
                        else None
                    if d_attr is not None and d_attr.attr == "setdefault" \
                            and self._is_counter_expr(d_attr.value, aliases) \
                            and node.args and \
                            isinstance(node.args[0], ast.Constant) and \
                            isinstance(node.args[0].value, str):
                        keys.append((node, node.args[0].value))
                    if d_attr is not None and d_attr.attr == "bump" and \
                            node.args and \
                            isinstance(node.args[0], ast.Constant) and \
                            isinstance(node.args[0].value, str):
                        keys.append((node, node.args[0].value))
                # counter-definition factory: def new_*_counters(): return {...}
                if isinstance(node, ast.Return) and node.value is not None \
                        and isinstance(scope, FUNC_DEFS) and \
                        scope.name.endswith("_counters"):
                    keys.extend(dict_keys(node.value))
                for child in ast.iter_child_nodes(node):
                    visit(child)

            for child in ast.iter_child_nodes(scope):
                visit(child)
            results.append((scope, keys))

        handle_scope(mod.tree)
        return results


ALL_CHECKERS = (
    LadderDisciplineChecker(),
    IntegrityProtocolChecker(),
    LockDisciplineChecker(),
    PrecisionContractChecker(),
    TraceSafetyChecker(),
    CounterRegistrationChecker(),
)
