"""CLI for the contract linter.

Usage::

    python -m tools.contract_lint src/ --baseline tools/contract_lint/baseline.json
    python -m tools.contract_lint src/repro/serve --select CL001
    python -m tools.contract_lint src/ --json findings.json   # CI artifact
    python -m tools.contract_lint src/ --write-baseline tools/contract_lint/baseline.json

Exit codes: 0 clean (or all findings baselined), 1 new findings (or a
stale baseline entry with --strict-baseline), 2 usage/internal error.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .checkers import ALL_CHECKERS
from .engine import Baseline, LintConfig, lint_paths


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.contract_lint",
        description="AST-based contract linter (ladder, integrity, lock, "
                    "precision, trace-safety, counter contracts).")
    parser.add_argument("paths", nargs="*", default=["src/"],
                        help="files or directories to lint (default: src/)")
    parser.add_argument("--baseline", metavar="JSON",
                        help="accepted-findings baseline to filter against")
    parser.add_argument("--write-baseline", metavar="JSON",
                        help="write all current findings as a fresh baseline "
                             "(justifications start as FIXME placeholders)")
    parser.add_argument("--json", metavar="JSON", dest="json_out",
                        help="write the full findings report as JSON "
                             "(CI artifact)")
    parser.add_argument("--select", action="append", default=None,
                        metavar="RULE", help="run only these rule ids/names "
                        "(repeatable)")
    parser.add_argument("--strict-baseline", action="store_true",
                        help="also fail on stale baseline entries")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule catalogue and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        for c in ALL_CHECKERS:
            doc = (c.__doc__ or "").strip().splitlines()[0]
            print(f"{c.rule}  {c.name:22s} {doc}")
        return 0

    try:
        findings = lint_paths(args.paths or ["src/"],
                              LintConfig(select=args.select))
    except SyntaxError as exc:
        print(f"error: cannot parse {exc.filename}:{exc.lineno}: {exc.msg}",
              file=sys.stderr)
        return 2

    if args.write_baseline:
        entries = Baseline.seed(findings)
        Path(args.write_baseline).write_text(
            json.dumps({"findings": entries}, indent=2) + "\n")
        print(f"wrote {len(entries)} baseline entries to "
              f"{args.write_baseline} (fill in the justifications)")
        return 0

    baseline = Baseline()
    if args.baseline:
        try:
            baseline = Baseline.load(args.baseline)
        except FileNotFoundError:
            print(f"error: baseline {args.baseline} not found",
                  file=sys.stderr)
            return 2
        except ValueError as exc:
            print(f"error: {exc}", file=sys.stderr)
            return 2

    new, accepted = baseline.split(findings)
    stale = baseline.unused(findings)

    if args.json_out:
        report = {
            "new": [f.to_json() for f in new],
            "accepted": [f.to_json() for f in accepted],
            "stale_baseline_entries": stale,
        }
        Path(args.json_out).write_text(json.dumps(report, indent=2) + "\n")

    for f in new:
        print(f.render())
    if accepted:
        print(f"({len(accepted)} baselined finding"
              f"{'s' if len(accepted) != 1 else ''} suppressed)")
    for e in stale:
        print(f"warning: stale baseline entry {e.get('rule')} "
              f"{e.get('path')} [{e.get('context')}] — no finding matches; "
              f"prune it", file=sys.stderr)

    if new:
        print(f"\n{len(new)} new contract violation"
              f"{'s' if len(new) != 1 else ''}.", file=sys.stderr)
        return 1
    if stale and args.strict_baseline:
        return 1
    print(f"contract lint clean: {len(findings)} finding(s), "
          f"all baselined" if findings else "contract lint clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
