"""Batched device pruning: per-query loop vs flat batch vs tree batch.

The device plane's pitch (ISSUE 1): at workload scale the pruning decision
itself must be cheap, so metadata is staged once per table version and Q
queries ride one batched kernel launch instead of Q stagings + Q launches.
ISSUE 7 adds the hierarchical tree plane on top: a group pre-pass prices
the batch against surviving partition groups instead of all P, so the
batched path stops collapsing linearly as P grows.

The workload models the paper's setting.  Partition stats are *clustered*
(per-column sorted minima, width a few multiples of the inter-partition
spacing) — Snowflake micro-partitions inherit natural clustering from
ingestion order, which is exactly what makes min/max pruning effective at
all.  Queries carry one narrow constraint with a *fixed absolute span*
(~SPAN_PARTS partitions regardless of P — production queries bound their
result set, they don't grow it with the table) plus wide extra
constraints.  Under that model the flat batch pays O(Q*P) while survivors
stay constant, which is precisely the regime the tree exploits.

Grid: P in {10k, 100k, 1M} x Q in {1, 16, 256} on the jnp ref backend
(the container has no TPU; the costs being amortized — host gather, f32
cast, H2D copy, dispatch — are real on every backend).  Acceptance gates:

- legacy: qps_batched >= 5x qps_loop at Q=256, P=100k;
- sublinear (ISSUE 7): qps_batched(P=1M) >= 0.5 * qps_batched(P=100k)
  at Q=256;
- dense guard (ISSUE 7): with >50% of groups surviving, the tree path
  skips its pre-pass launches entirely, so its wall time stays within
  ~1.15x of the flat launch (two launches must never be slower than one).

Emits machine-readable ``BENCH_batched_prune.json`` next to the CSV rows.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core.device_stats import (
    TREE_FANOUT, DeviceStats, plane_capacity, tree_entry_for)
from repro.core.metadata import ColumnMeta, PartitionStats
from repro.kernels import ops

from .common import emit

# This module writes its own richer JSON artifact (grid + acceptance);
# benchmarks/run.py sees this flag and skips its generic per-module JSON.
EMITS_OWN_JSON = True

GRID_P = (10_000, 100_000, 1_000_000)
GRID_Q = (1, 16, 256)
C = 6                 # metadata columns
MAX_K = 4             # constraints per query (bucketed to Kb=4)
LOOP_SAMPLE = 32      # per-query loop cost is constant: time a sample,
                      # extrapolate to Q (keeps the 1M-partition cell sane)
SPAN_PARTS = 512      # absolute survivor span of the narrow constraint
DENSE_Q = 64          # batch size of the dense-survivor guard cell
DENSE_MAX_RATIO = 1.15


def make_stats(P: int, rng) -> PartitionStats:
    """Clustered stats: sorted per-column minima over [-1000, 1000]."""
    cols = [ColumnMeta(f"c{i}", "float") for i in range(C)]
    spacing = 2000.0 / P
    mins = np.empty((P, C), dtype=np.float64)
    for ci in range(C):
        mins[:, ci] = np.sort(rng.uniform(-1000, 1000, size=P))
    maxs = mins + spacing * rng.uniform(0.5, 4.0, size=(P, C))
    return PartitionStats(
        columns=cols,
        mins=mins,
        maxs=maxs,
        null_counts=np.zeros((P, C), dtype=np.int64),
        row_counts=np.full(P, 100, dtype=np.int64),
    )


def make_queries(Q: int, rng, P: int):
    """Q conjunctive-range queries, 1..MAX_K constraints each.

    The first constraint is narrow — fixed absolute span of ~SPAN_PARTS
    partitions on a random column; any extras are wide (full-domain) on
    other columns.  Bounds are f32-exact.
    """
    width = np.float32(2000.0 * SPAN_PARTS / P)
    out = []
    for _ in range(Q):
        k = int(rng.integers(1, MAX_K + 1))
        cids = rng.choice(C, size=k, replace=False)
        lo0 = np.float32(rng.uniform(-1000.0, 1000.0 - float(width)))
        q = [(int(cids[0]), float(lo0), float(np.float32(lo0 + width)))]
        for c in cids[1:]:
            q.append((int(c), float(np.float32(rng.uniform(-1600, -1200))),
                      float(np.float32(rng.uniform(1200, 1600)))))
        out.append(q)
    return out


def make_dense_queries(Q: int, rng):
    """Wide-only queries: every constraint keeps the whole domain, so
    >50% of groups survive and the tree path must decline its pre-pass."""
    out = []
    for _ in range(Q):
        k = int(rng.integers(1, MAX_K + 1))
        cids = rng.choice(C, size=k, replace=False)
        out.append([(int(c), float(np.float32(rng.uniform(-1600, -1200))),
                     float(np.float32(rng.uniform(1200, 1600))))
                    for c in cids])
    return out


def _time(fn, repeats: int) -> float:
    """Median wall seconds of fn()."""
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def _dense_cell(grid_p, rng) -> dict:
    """Dense-survivor guard: tree wall time vs flat when >50% of groups
    survive the coarse check.  Runs at the largest grid P in [1024, 200k]
    (big enough for tree eligibility, small enough to repeat)."""
    eligible = [P for P in grid_p if 1024 <= P <= 200_000]
    if not eligible:
        return dict(skipped=True)
    P = max(eligible)
    stats = make_stats(P, rng)
    dstats = DeviceStats.stage(stats, capacity=plane_capacity(P))
    tree = tree_entry_for(dstats)
    queries = make_dense_queries(DENSE_Q, rng)

    def flat():
        ops.prune_ranges_batched_device(queries, dstats, mode="ref")

    def treed():
        ops.prune_ranges_batched_tree(queries, dstats, tree, mode="ref")

    flat(), treed()                           # warm jit caches
    # Interleave the repeats: the ratio is the pinned quantity, and
    # back-to-back blocks let clock/load drift masquerade as overhead.
    fs, ts = [], []
    for _ in range(9):
        t0 = time.perf_counter(); flat(); fs.append(time.perf_counter() - t0)
        t0 = time.perf_counter(); treed(); ts.append(time.perf_counter() - t0)
    s_flat, s_tree = float(np.median(fs)), float(np.median(ts))
    note = ops.last_tree_stats()
    return dict(
        P=P, Q=DENSE_Q,
        us_total_flat=s_flat * 1e6,
        us_total_tree=s_tree * 1e6,
        tree_over_flat=s_tree / s_flat,
        tree_path=note.get("path"),
        coarse_density=note.get("coarse_density"),
    )


def run(grid_p=GRID_P, grid_q=GRID_Q, csv: bool = True,
        json_path: str = "BENCH_batched_prune.json",
        loop_sample: int = LOOP_SAMPLE):
    rng = np.random.default_rng(0)
    rows, cells = [], []
    for P in grid_p:
        stats = make_stats(P, rng)
        # Pow-2 capacity (the cache's own staging geometry) so the tree
        # fanout divides the plane; dense capacity P generally doesn't.
        dstats = DeviceStats.stage(stats, capacity=plane_capacity(P))
        tree = tree_entry_for(dstats)
        repeats = 3 if P <= 100_000 else 1
        for Q in grid_q:
            queries = make_queries(Q, rng, P)

            # Regime A — per-query loop: every query re-gathers the [K, P]
            # slice on the host, re-uploads, launches the 1-query kernel.
            sample = queries[:min(Q, loop_sample)]

            def loop():
                for ranges in sample:
                    ops.prune_ranges_device(ranges, stats, mode="ref")

            loop()                            # warm jit caches
            s_loop = _time(loop, repeats) / len(sample)   # sec per query
            qps_loop = 1.0 / s_loop

            # Regime B — flat batch: resident planes, one launch over the
            # full [C, Pc] plane for all Q.
            def flat():
                ops.prune_ranges_batched_device(queries, dstats, mode="ref")

            flat()                            # warm jit caches
            s_flat = _time(flat, repeats)

            # Regime C — tree batch (the shipped batched path): host
            # coarse check, then gathered pre-pass + leaf eval over
            # surviving groups only.
            def treed():
                ops.prune_ranges_batched_tree(queries, dstats, tree,
                                              mode="ref")

            treed()                           # warm jit caches
            s_tree = _time(treed, repeats)
            note = ops.last_tree_stats()
            qps_batched = Q / s_tree

            cell = dict(
                P=P, Q=Q,
                us_per_query_loop=s_loop * 1e6,
                us_total_flat=s_flat * 1e6,
                us_total_batched=s_tree * 1e6,
                qps_loop=qps_loop,
                qps_flat=Q / s_flat,
                qps_batched=qps_batched,
                speedup=qps_batched / qps_loop,
                tree_vs_flat=s_flat / s_tree,
                tree_path=note.get("path"),
            )
            cells.append(cell)
            rows.append((
                f"batched_prune_P{P}_Q{Q}",
                s_tree * 1e6,
                f"qps_batched={qps_batched:.0f} qps_loop={qps_loop:.0f} "
                f"x{cell['speedup']:.1f} tree_vs_flat="
                f"{cell['tree_vs_flat']:.1f}",
            ))
    dense = _dense_cell(grid_p, rng)
    if csv and not dense.get("skipped"):
        rows.append((
            f"batched_prune_dense_P{dense['P']}_Q{dense['Q']}",
            dense["us_total_tree"],
            f"tree_over_flat={dense['tree_over_flat']:.2f} "
            f"path={dense['tree_path']}",
        ))
    if csv:
        emit(rows)
    if json_path:
        def cell_at(P, Q):
            hits = [c for c in cells if c["P"] == P and c["Q"] == Q]
            return hits[0] if hits else None

        legacy = cell_at(100_000, 256)
        big = cell_at(1_000_000, 256)
        sub_ratio = (big["qps_batched"] / legacy["qps_batched"]
                     if legacy and big else None)
        payload = dict(
            bench="batched_prune",
            backend="ref",
            columns=C,
            max_constraints=MAX_K,
            loop_sample=loop_sample,
            span_parts=SPAN_PARTS,
            tree_fanout=TREE_FANOUT,
            grid=cells,
            dense_cell=dense,
            acceptance=dict(
                batched_speedup=dict(
                    target="qps_batched >= 5x qps_loop at Q=256, P=100k",
                    speedup=legacy["speedup"] if legacy else None,
                    passed=(bool(legacy["speedup"] >= 5.0)
                            if legacy else None),
                ),
                sublinear=dict(
                    target=("qps_batched(P=1M) >= 0.5 * qps_batched"
                            "(P=100k) at Q=256"),
                    ratio=sub_ratio,
                    passed=(bool(sub_ratio >= 0.5)
                            if sub_ratio is not None else None),
                ),
                dense_guard=dict(
                    target=(f"tree wall time <= {DENSE_MAX_RATIO}x flat "
                            "when >50% of groups survive"),
                    tree_over_flat=dense.get("tree_over_flat"),
                    passed=(bool(dense["tree_over_flat"]
                                 <= DENSE_MAX_RATIO)
                            if not dense.get("skipped") else None),
                ),
            ),
        )
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
    return rows, cells


def main():
    # BENCH_JSON_DIR is set by benchmarks/run.py from --json-dir; empty
    # means JSON emission is disabled.  Standalone runs default to CWD.
    json_dir = os.environ.get("BENCH_JSON_DIR", ".")
    json_path = (os.path.join(json_dir, "BENCH_batched_prune.json")
                 if json_dir else "")
    if os.environ.get("BENCH_CI"):
        # CI sublinear-lane smoke: one 1M cell plus its 100k reference,
        # small Q and loop sample so the lane stays fast.
        run(grid_p=(100_000, 1_000_000), grid_q=(64,), json_path=json_path,
            loop_sample=4)
    else:
        run(json_path=json_path)


if __name__ == "__main__":
    main()
