"""Batched device pruning vs the per-query staging loop.

The device plane's pitch (ISSUE 1): at workload scale the pruning decision
itself must be cheap, so metadata is staged once per table version and Q
queries ride one batched kernel launch instead of Q stagings + Q launches.
This bench measures queries/sec of both regimes over P in {10k, 100k, 1M}
partitions and Q in {1, 16, 256} queries, on the jnp ref backend (the
container has no TPU; the staging overhead being amortized — host gather,
f32 cast, H2D copy, dispatch — is real on every backend).

Emits machine-readable ``BENCH_batched_prune.json`` next to the CSV rows.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core.device_stats import DeviceStats
from repro.core.metadata import ColumnMeta, PartitionStats
from repro.kernels import ops

from .common import emit

# This module writes its own richer JSON artifact (grid + acceptance);
# benchmarks/run.py sees this flag and skips its generic per-module JSON.
EMITS_OWN_JSON = True

GRID_P = (10_000, 100_000, 1_000_000)
GRID_Q = (1, 16, 256)
C = 6                 # metadata columns
MAX_K = 4             # constraints per query (bucketed to Kb=4)
LOOP_SAMPLE = 32      # per-query loop cost is constant: time a sample,
                      # extrapolate to Q (keeps the 1M-partition cell sane)


def make_stats(P: int, rng) -> PartitionStats:
    cols = [ColumnMeta(f"c{i}", "float") for i in range(C)]
    mins = rng.uniform(-1000, 1000, size=(P, C)).astype(np.float32)
    maxs = mins + rng.uniform(0, 100, size=(P, C)).astype(np.float32)
    return PartitionStats(
        columns=cols,
        mins=mins.astype(np.float64),
        maxs=maxs.astype(np.float64),
        null_counts=np.zeros((P, C), dtype=np.int64),
        row_counts=np.full(P, 100, dtype=np.int64),
    )


def make_queries(Q: int, rng):
    """Q conjunctive-range queries; f32-exact bounds, 1..MAX_K constraints."""
    out = []
    for _ in range(Q):
        k = int(rng.integers(1, MAX_K + 1))
        cids = rng.choice(C, size=k, replace=False)
        lo = rng.uniform(-1000, 1000, size=k).astype(np.float32)
        hi = (lo + rng.uniform(0, 500, size=k).astype(np.float32)).astype(np.float32)
        out.append([(int(c), float(l), float(h))
                    for c, l, h in zip(cids, lo, hi)])
    return out


def _time(fn, repeats: int) -> float:
    """Median wall seconds of fn()."""
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def run(grid_p=GRID_P, grid_q=GRID_Q, csv: bool = True,
        json_path: str = "BENCH_batched_prune.json"):
    rng = np.random.default_rng(0)
    rows, cells = [], []
    for P in grid_p:
        stats = make_stats(P, rng)
        dstats = DeviceStats.stage(stats)     # once per table version
        repeats = 3 if P <= 100_000 else 1
        for Q in grid_q:
            queries = make_queries(Q, rng)

            # Regime A — per-query loop: every query re-gathers the [K, P]
            # slice on the host, re-uploads, launches the 1-query kernel.
            sample = queries[:min(Q, LOOP_SAMPLE)]

            def loop():
                for ranges in sample:
                    ops.prune_ranges_device(ranges, stats, mode="ref")

            loop()                            # warm jit caches
            s_loop = _time(loop, repeats) / len(sample)   # sec per query
            qps_loop = 1.0 / s_loop

            # Regime B — batched: resident planes, one launch for all Q.
            def batched():
                ops.prune_ranges_batched_device(queries, dstats, mode="ref")

            batched()                         # warm jit caches
            s_batched = _time(batched, repeats)
            qps_batched = Q / s_batched

            cell = dict(
                P=P, Q=Q,
                us_per_query_loop=s_loop * 1e6,
                us_total_batched=s_batched * 1e6,
                qps_loop=qps_loop,
                qps_batched=qps_batched,
                speedup=qps_batched / qps_loop,
            )
            cells.append(cell)
            rows.append((
                f"batched_prune_P{P}_Q{Q}",
                s_batched * 1e6,
                f"qps_batched={qps_batched:.0f} qps_loop={qps_loop:.0f} "
                f"x{cell['speedup']:.1f}",
            ))
    if csv:
        emit(rows)
    if json_path:
        accept = [c for c in cells if c["P"] == 100_000 and c["Q"] == 256]
        payload = dict(
            bench="batched_prune",
            backend="ref",
            columns=C,
            max_constraints=MAX_K,
            loop_sample=LOOP_SAMPLE,
            grid=cells,
            acceptance=dict(
                target="qps_batched >= 5x qps_loop at Q=256, P=100k",
                speedup=accept[0]["speedup"] if accept else None,
                passed=bool(accept and accept[0]["speedup"] >= 5.0),
            ),
        )
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
    return rows, cells


def main():
    # BENCH_JSON_DIR is set by benchmarks/run.py from --json-dir; empty
    # means JSON emission is disabled.  Standalone runs default to CWD.
    json_dir = os.environ.get("BENCH_JSON_DIR", ".")
    run(json_path=os.path.join(json_dir, "BENCH_batched_prune.json")
        if json_dir else "")


if __name__ == "__main__":
    main()
