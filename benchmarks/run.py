"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per row and, for each module,
writes a machine-readable ``BENCH_<module>.json`` (parsed from the same
rows) into ``--json-dir`` so CI and later sessions can diff numbers
without scraping stdout.  Run with ``PYTHONPATH=src python -m
benchmarks.run`` (add ``--only fig13`` to filter, ``--json-dir ''`` to
disable JSON emission).
"""

from __future__ import annotations

import argparse
import contextlib
import io
import json
import os
import sys
import time

MODULES = [
    "fig01_pruning_ratios",
    "fig03_adaptive_tree",
    "fig04_filter_impact",
    "tab01_limit_frequency",
    "tab02_limit_applicability",
    "fig06_k_cdf",
    "fig08_topk_sorting",
    "fig09_topk_impact",
    "fig10_join_impact",
    "fig11_flow",
    "fig13_tpch",
    "sec81_iceberg",
    "sec82_predicate_cache",
    "kernels_bench",
    "bench_batched_prune",
    "bench_runtime_prune",
]


class _Tee(io.TextIOBase):
    """Write-through to several text sinks (live stdout + capture buffer)."""

    def __init__(self, *sinks):
        self.sinks = sinks

    def write(self, s):
        for sink in self.sinks:
            sink.write(s)
        return len(s)

    def flush(self):
        for sink in self.sinks:
            sink.flush()


def parse_csv_rows(text: str):
    """name,us_per_call,derived lines -> [{name, us_per_call, derived}]."""
    rows = []
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        parts = line.split(",", 2)
        if len(parts) != 3:
            continue
        name, us, derived = parts
        try:
            us_val = float(us)
        except ValueError:
            continue
        rows.append(dict(name=name, us_per_call=us_val, derived=derived))
    return rows


def write_module_json(json_dir: str, name: str, rows, seconds: float) -> str:
    path = os.path.join(json_dir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(dict(module=name, seconds=seconds, rows=rows), f, indent=2)
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on module names")
    ap.add_argument("--json-dir", default=".",
                    help="directory for BENCH_<module>.json files "
                         "('' disables)")
    args = ap.parse_args()
    # Modules that write their own artifact (EMITS_OWN_JSON) resolve its
    # location from this env var, so --json-dir governs them too.
    os.environ["BENCH_JSON_DIR"] = args.json_dir

    print("name,us_per_call,derived")
    failures = []
    for name in MODULES:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        buf = io.StringIO()
        # Tee, don't buffer: rows keep streaming live (and survive an
        # interrupt mid-module) while the copy feeds the JSON writer.
        tee = _Tee(sys.stdout, buf)
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            with contextlib.redirect_stdout(tee):
                mod.main()
        except Exception as e:  # noqa: BLE001
            failures.append((name, e))
            print(f"{name},0,ERROR:{type(e).__name__}:{e}")
            continue
        dt = time.time() - t0
        if args.json_dir and not getattr(mod, "EMITS_OWN_JSON", False):
            # A JSON write failure must not fail a benchmark that
            # succeeded.  Modules that write their own richer artifact
            # (EMITS_OWN_JSON) are skipped to avoid near-duplicate files.
            try:
                write_module_json(args.json_dir, name,
                                  parse_csv_rows(buf.getvalue()), dt)
            except OSError as e:
                print(f"# {name}: JSON write failed: {e}", file=sys.stderr)
        print(f"# {name} done in {dt:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(f"{len(failures)} benchmark module(s) failed")


if __name__ == "__main__":
    main()
