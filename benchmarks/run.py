"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per row.  Run with
``PYTHONPATH=src python -m benchmarks.run`` (add ``--only fig13`` to
filter).
"""

from __future__ import annotations

import argparse
import sys
import time

MODULES = [
    "fig01_pruning_ratios",
    "fig03_adaptive_tree",
    "fig04_filter_impact",
    "tab01_limit_frequency",
    "tab02_limit_applicability",
    "fig06_k_cdf",
    "fig08_topk_sorting",
    "fig09_topk_impact",
    "fig10_join_impact",
    "fig11_flow",
    "fig13_tpch",
    "sec81_iceberg",
    "sec82_predicate_cache",
    "kernels_bench",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on module names")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failures = []
    for name in MODULES:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main()
            print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)
        except Exception as e:  # noqa: BLE001
            failures.append((name, e))
            print(f"{name},0,ERROR:{type(e).__name__}:{e}")
    if failures:
        raise SystemExit(f"{len(failures)} benchmark module(s) failed")


if __name__ == "__main__":
    main()
