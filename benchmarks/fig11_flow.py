"""Figure 11 + the Sec. 1 headline: the combined pruning flow, and the
fleet-wide fraction of micro-partitions pruned.

Two aggregates, because they answer different questions:
  * technique-combination shares (Fig. 11 proper): per-QUERY shares over
    the Table-1-calibrated query mix;
  * fleet-wide partition pruning (paper: 99.4%): partition-WEIGHTED over
    a fleet model where table sizes span orders of magnitude and scan
    volume concentrates on big, time-clustered tables queried through
    tight windows (the reason petabyte warehouses are operable at all —
    nobody routinely full-scans their biggest tables; full scans and
    exploratory queries hit the small/mid tiers).  Fleet mix below:
    big tier 97% tight-window / 3% full; mid tier the Fig. 4 predicate
    mix; small tier unfiltered dashboard scans.
"""

from __future__ import annotations

import numpy as np

from repro.core.flow import PruningPipeline, Query, TableScanSpec
from repro.data.generator import make_events_table

from .common import emit, timeit
from .workload import (sample_filter_pred, sample_join_query,
                       sample_limit_query, sample_topk_query, small_table,
                       tables, tight_window_pred)

_BIG = {}


def big_table(seed=8):
    if seed not in _BIG:
        rng = np.random.default_rng(seed + 17)
        # 4000 partitions: the "petabyte fact table" tier (scaled down)
        _BIG[seed] = make_events_table(rng, n_rows=400_000,
                                       rows_per_partition=100,
                                       ts_clustering=0.998,
                                       user_clustering=0.995)
    return _BIG[seed]


def run(n: int = 120, seed: int = 8, csv: bool = True):
    rng = np.random.default_rng(seed)
    events, users = tables(seed)
    big = big_table(seed)
    small = small_table(seed)
    pipe = PruningPipeline()
    combos: dict = {}
    total_parts = 0
    total_after = 0
    for _ in range(n):
        u = rng.random()
        if u < 0.0260:
            q = sample_limit_query(rng, events)
        elif u < 0.0260 + 0.0555:
            q = sample_topk_query(rng, big, pred_prob=0.8)
        elif u < 0.20:
            q = sample_join_query(rng, big, users)
            if rng.random() < 0.95:   # probe side usually time-windowed too
                q.scans["events"] = TableScanSpec(big, tight_window_pred(rng))
        elif u < 0.73:
            # big-tier scan: overwhelmingly tight windows (full scans of
            # the biggest tables are operationally rare)
            pred = tight_window_pred(rng) if rng.random() < 0.995 \
                else _full_pred()
            q = Query(scans={"events": TableScanSpec(big, pred)})
        elif u < 0.92:
            q = Query(scans={"events": TableScanSpec(
                events, sample_filter_pred(rng, events))})
        else:
            q = Query(scans={"events": TableScanSpec(small, _full_pred())})
        rep = pipe.run(q)
        fired = []
        for scan in rep.per_scan.values():
            for tech, r in scan.items():
                if r.applied and r.ratio > 0 and tech not in fired:
                    fired.append(tech)
        if rep.topk is not None and len(rep.topk.skipped) and "topk" not in fired:
            fired.append("topk")
        key = "+".join(sorted(fired)) or "none"
        combos[key] = combos.get(key, 0) + 1
        total_parts += sum(s.table.num_partitions
                           for s in rep._scan_specs.values())
        remaining = sum(len(ss) for ss in rep.scan_sets.values())
        if rep.topk is not None:
            remaining -= len(rep.topk.skipped)
        total_after += remaining
    overall = 1.0 - total_after / total_parts
    us = timeit(lambda: pipe.run(sample_limit_query(rng, events)))
    rows = [(f"fig11_{k}", us, f"share={v / n:.3f}")
            for k, v in sorted(combos.items(), key=lambda kv: -kv[1])]
    rows.append(("fig11_overall_partition_pruning", us,
                 f"{overall:.4f} (paper fleet-wide: 0.994)"))
    if csv:
        emit(rows)
    return combos, overall


def _full_pred():
    from repro.core import expr as E
    return E.true()


def main():
    run()


if __name__ == "__main__":
    main()
