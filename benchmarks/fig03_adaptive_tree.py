"""Figure 3 / Sec. 3.2: adaptive pruning-tree reordering and cutoff.

Measures the deterministic work model (partition-evaluations x per-node
cost) for the same predicate under: fixed written order, adaptive
reordering, and reordering + cutoff — on a predicate shaped like the
paper's example: an expensive unselective branch, a cheap selective one,
and an OR the cutoff must never touch.
"""

from __future__ import annotations

import numpy as np

from repro.core import expr as E
from repro.core.metadata import NO_MATCH
from repro.core.prune_filter import eval_tv
from repro.core.prune_tree import AdaptivePruner
from repro.data.generator import make_events_table

from .common import emit, timeit


def build_pred():
    # p1: expensive, unselective (complex arithmetic, passes everything)
    p1 = (E.col("score") * 3.0 + E.col("score") * 2.0 + E.col("score")) >= 0.0
    # p2: cheap, highly selective (tight recent window on clustered ts)
    p2 = E.col("ts") >= 9_900_000
    # p3 | p4: an OR branch (children may be reordered, never cut)
    p3 = E.startswith(E.col("status"), "err")
    p4 = E.startswith(E.col("status"), "crit")
    return E.And((p1, p2, E.Or((p3, p4))))


def run(csv: bool = True):
    rng = np.random.default_rng(0)
    events = make_events_table(rng, n_rows=100_000, rows_per_partition=250)
    pred = build_pred()
    exact = eval_tv(pred, events.stats)

    results = {}
    for label, kw in (
        ("fixed", dict(reorder=False, cutoff=False)),
        ("reorder", dict(reorder=True, cutoff=False)),
        ("reorder+cutoff", dict(reorder=True, cutoff=True, scan_cost=50.0)),
    ):
        pruner = AdaptivePruner(pred, **kw)
        res = pruner.run(events.stats, batch_size=25)
        # correctness: never over-prunes vs exact evaluation
        assert not ((res.tv == NO_MATCH) & (exact != NO_MATCH)).any()
        results[label] = (res.work_units, res.leaf_report)

    us = timeit(lambda: AdaptivePruner(pred).run(events.stats, batch_size=25))
    base = results["fixed"][0]
    rows = []
    for label, (work, report) in results.items():
        disabled = sum(r["disabled"] for r in report)
        rows.append((f"fig03_{label.replace('+', '_')}", us,
                     f"work={work:.0f} ({work / base:.2f}x of fixed) "
                     f"disabled_leaves={disabled}"))
    # OR children must survive cutoff (the paper's safety rule)
    _, report = results["reorder+cutoff"]
    or_leaves = [r for r in report if "err" in r["pred"] or "crit" in r["pred"]]
    assert not any(r["disabled"] for r in or_leaves)
    rows.append(("fig03_or_children_never_cut", us,
                 f"verified over {len(or_leaves)} OR leaves"))
    if csv:
        emit(rows)
    return results


def main():
    run()


if __name__ == "__main__":
    main()
