"""Figure 4: impact of filter pruning on SELECT queries with >= 1
predicate, ratio relative to ALL partitions the query touches.

Paper reference: ~36% of queries prune >= ~90%; ~27% have prunable
filters but zero reduction.
"""

from __future__ import annotations

import numpy as np

from repro.core.flow import PruningPipeline, Query, TableScanSpec

from .common import dist_stats, emit, timeit
from .workload import sample_filter_pred, tables


def run(n_queries: int = 150, seed: int = 1, csv: bool = True):
    rng = np.random.default_rng(seed)
    events, _ = tables(seed)
    pipe = PruningPipeline()
    ratios = []
    for _ in range(n_queries):
        pred = sample_filter_pred(rng, events)
        rep = pipe.run(Query(scans={"events": TableScanSpec(events, pred)}))
        ratios.append(rep.per_scan["events"]["filter"].ratio)
    a = np.asarray(ratios)
    frac_ge90 = float((a >= 0.9).mean())
    frac_zero = float((a == 0.0).mean())
    us = timeit(lambda: pipe.run(
        Query(scans={"events": TableScanSpec(
            events, sample_filter_pred(rng, events))})))
    rows = [
        ("fig04_filter_cdf", us, dist_stats(ratios)),
        ("fig04_frac_ge90", us, f"{frac_ge90:.3f} (paper ~0.36)"),
        ("fig04_frac_zero", us, f"{frac_zero:.3f} (paper ~0.27)"),
    ]
    if csv:
        emit(rows)
    return a


def main():
    run()


if __name__ == "__main__":
    main()
