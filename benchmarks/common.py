"""Benchmark harness utilities: timing + the name,us_per_call,derived CSV."""

from __future__ import annotations

import time
from typing import Callable, Iterable, List, Tuple

import numpy as np


def timeit(fn: Callable, repeats: int = 3) -> float:
    """Median wall time of fn() in microseconds."""
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def emit(rows: Iterable[Tuple[str, float, str]]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


def dist_stats(vals: List[float]) -> str:
    a = np.asarray(vals, dtype=np.float64)
    if a.size == 0:
        return "n=0"
    return (f"n={a.size} mean={a.mean():.3f} median={np.median(a):.3f} "
            f"p25={np.percentile(a, 25):.3f} p75={np.percentile(a, 75):.3f}")
