"""Figure 1: pruning ratios of the four techniques for ELIGIBLE queries.

Paper reference values (means over eligible queries): filter ~0.99,
LIMIT ~0.70, top-k ~0.77, join ~0.79; LIMIT with high mean but low median.
"""

from __future__ import annotations

import numpy as np

from repro.core.flow import PruningPipeline

from .common import dist_stats, emit, timeit
from .workload import (sample_filter_pred, sample_join_query,
                       sample_limit_query, sample_topk_query, tables)
from repro.core.flow import Query, TableScanSpec


def run(n_queries: int = 60, seed: int = 0, csv: bool = True):
    rng = np.random.default_rng(seed)
    events, users = tables(seed)
    pipe = PruningPipeline()
    ratios = {"filter": [], "limit": [], "topk": [], "join": []}

    from .workload import tight_window_pred
    for _ in range(n_queries):
        # eligible-filter population at partition-volume scale is
        # dominated by time-windowed scans of clustered fact tables
        pred = tight_window_pred(rng) if rng.random() < 0.7 \
            else sample_filter_pred(rng, events)
        q = Query(scans={"events": TableScanSpec(events, pred)})
        rep = pipe.run(q)
        r = rep.per_scan["events"]["filter"]
        if r.applied and r.ratio > 0:          # eligible = pruned something
            ratios["filter"].append(r.ratio)

    for _ in range(n_queries):
        q = sample_limit_query(rng, events)
        rep = pipe.run(q)
        r = rep.per_scan["events"].get("limit")
        if r and r.applied:
            ratios["limit"].append(r.ratio)

    for _ in range(n_queries // 2):
        q = sample_topk_query(rng, events)
        rep = pipe.run(q)
        r = rep.per_scan["events"].get("topk")
        if r and r.applied and len(rep.scan_sets["events"]) > 1:
            ratios["topk"].append(r.ratio)

    for _ in range(n_queries // 2):
        q = sample_join_query(rng, events, users)
        rep = pipe.run(q)
        r = rep.per_scan["events"].get("join")
        if r and r.applied:
            ratios["join"].append(r.ratio)

    us = timeit(lambda: pipe.run(sample_limit_query(rng, events)), repeats=3)
    rows = [(f"fig01_{k}", us, dist_stats(v)) for k, v in ratios.items()]
    if csv:
        emit(rows)
    return {k: v for k, v in ratios.items()}


def main():
    run()


if __name__ == "__main__":
    main()
