"""Kernel microbenchmarks: device (jnp ref / interpret kernel) vs the
host engine, plus the pass-count halving of one-pass fully-matching.

On this CPU container the interpret-mode kernel timing is NOT a TPU
number — the derived columns therefore report op-level quantities
(partitions/s on the jnp path, bytes of metadata touched) that transfer,
and EXPERIMENTS.md §Perf reasons about the TPU roofline for the kernels
analytically.
"""

from __future__ import annotations

import numpy as np

from repro.core import expr as E
from repro.core.prune_filter import (eval_ranges_tv, eval_tv, extract_ranges,
                                     fully_matching_two_pass)
from repro.data.generator import make_events_table
from repro.kernels import ops, ref

from .common import emit, timeit


def run(P: int = 100_000, csv: bool = True):
    rng = np.random.default_rng(0)
    events = make_events_table(rng, n_rows=P, rows_per_partition=1,
                               ts_clustering=0.99)
    stats = events.stats
    pred = (E.col("ts") >= 9_000_000) & (E.col("user_id") >= 100_000) \
        & (E.col("user_id") <= 400_000)
    ranges = extract_ranges(pred, stats)

    us_host = timeit(lambda: eval_ranges_tv(ranges, stats))
    us_dev = timeit(lambda: ops.prune_ranges_device(ranges, stats, mode="ref"))
    lo, hi, mins, maxs, nullable = ops.stage_ranges(ranges, stats)
    import jax
    ref_jit = jax.jit(ref.minmax_prune_ref)
    ref_jit(lo, hi, mins, maxs, nullable).block_until_ready()
    us_dev_hot = timeit(
        lambda: ref_jit(lo, hi, mins, maxs, nullable).block_until_ready())

    # one-pass vs two-pass fully-matching (DESIGN.md §6.1)
    us_one = timeit(lambda: eval_tv(pred, stats))
    us_two = timeit(lambda: (eval_tv(pred, stats),
                             fully_matching_two_pass(pred, stats)))

    # top-k boundary kernel staging
    vals = events.data["num_sightings"].astype(np.float32)
    rows = ops.build_block_topk(vals[: 20_000], np.arange(0, 20_001, 100), 8)
    order = np.argsort(-rows[:, 0])
    us_topk = timeit(lambda: ops.topk_boundary_device(rows[order], mode="ref"))
    us_topk_prefix = timeit(
        lambda: ops.topk_boundary_device(rows[order], mode="prefix"))

    meta_bytes = P * stats.num_columns * 8 * 2
    rows_out = [
        ("kern_minmax_host_numpy", us_host, f"P={P} {P / us_host:.0f} parts/us"),
        ("kern_minmax_jnp_cold", us_dev, "includes staging H->D"),
        ("kern_minmax_jnp_hot", us_dev_hot,
         f"{meta_bytes / (us_dev_hot * 1e-6) / 1e9:.2f} GB/s metadata"),
        ("kern_fully_matching_one_pass", us_one, "single metadata pass"),
        ("kern_fully_matching_two_pass", us_two,
         f"x{us_two / us_one:.2f} of one-pass (paper needs both passes)"),
        ("kern_topk_boundary_seq", us_topk, "lax.scan formulation"),
        ("kern_topk_boundary_prefix", us_topk_prefix,
         f"associative-scan, x{us_topk / max(us_topk_prefix, 1e-9):.2f} vs seq"),
    ]
    if csv:
        emit(rows_out)
    return rows_out


def main():
    run()


if __name__ == "__main__":
    main()
