"""Unified runtime pruning engine vs the per-query host loop.

The technique-executor engine's pitch (ISSUE 2): filter, JOIN, and top-k
pruning share one device-resident metadata plane, and a workload's
pruning runs as a handful of batched launches per *stage* — bounded by
the number of distinct tables, not queries.  This bench drives a mixed
filter+join+topk workload through both regimes over a P x Q grid:

  * Regime A — per-query host loop: ``PruningPipeline()`` (host mode),
    one full pipeline per query (the classic engine);
  * Regime B — batched engine: ``PruningService.run_batch`` with a
    device pipeline — filter ranges, join overlap, and top-k boundary
    init each batched per table group against resident planes.

A dedicated Bloom cell (ISSUE 3) isolates the blocked-Bloom JOIN path:
every build side exceeds the distinct limit, so regime A runs the
per-query host matcher while regime B issues one batched
``bloom_probe_batched`` enumeration per table group — the JSON reports
the qps delta and the launch/fallback attribution.

Run on the jnp ref backend (the container has no TPU); the overheads
being amortized — per-query predicate evaluation over [P] stats, staging,
Python dispatch — are real on every backend.  Emits machine-readable
``BENCH_runtime_prune.json``.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import expr as E
from repro.core.flow import JoinSpec, PruningPipeline, Query, TableScanSpec
from repro.data.generator import make_events_table, make_users_table
from repro.data.table import Table
from repro.serve.frontend import ServingFrontend
from repro.serve.prune_service import PruningService

from .common import emit

# This module writes its own richer JSON artifact (grid + acceptance);
# benchmarks/run.py sees this flag and skips its generic per-module JSON.
EMITS_OWN_JSON = True

GRID_P = (10_000, 100_000)
GRID_Q = (16, 64, 256)
TS_MAX = 10_000_000
LOOP_SAMPLE = 48      # host per-query cost is constant: time a sample,
                      # extrapolate to Q (keeps big cells sane)

_TABLES = {}


def tables(P: int):
    """One big fact table with P partitions + a small dimension table."""
    if P not in _TABLES:
        rng = np.random.default_rng(7)
        events = make_events_table(rng, n_rows=P, rows_per_partition=1,
                                   ts_clustering=0.995, user_clustering=0.99)
        users = make_users_table(rng, n_rows=2000, rows_per_partition=100)
        _TABLES[P] = (events, users)
    return _TABLES[P]


def make_queries(Q: int, events, users, rng):
    """Mixed workload: ~62% filter, ~25% join, ~12% top-k queries
    (runtime techniques oversampled vs the paper's Table 1 so the join
    and top-k stages are well represented in every cell).

    Predicates are production-style tight windows (the paper's Sec. 1
    point: real filters are very selective), so runtime stages operate on
    already-small scan sets and the per-query cost is dominated by the
    metadata math this engine batches.
    """
    qs = []
    for i in range(Q):
        frac = float(np.exp(rng.normal(np.log(0.004), 1.0)))
        lo = TS_MAX * (1 - min(frac, 1.0))
        # int/dictionary columns only: their bounds snap to integers and
        # cast to f32 exactly, so the device path proves the same FULL
        # matches as the host oracle (core.device_stats contract).
        pred = (E.col("ts") >= lo) & (E.col("ts") <= TS_MAX) \
            & (E.col("user_id") >= 1000) & (E.col("num_sightings") >= 0)
        kind = i % 8
        if kind in (2, 6):
            lo_a = int(rng.integers(20, 75))
            upred = (E.col("age") >= lo_a) & (E.col("age") <= lo_a + 4)
            qs.append(Query(
                scans={"events": TableScanSpec(events, pred),
                       "users": TableScanSpec(users, upred)},
                join=JoinSpec("users", "events", "id", "user_id")))
        elif kind == 4:
            qs.append(Query(scans={"events": TableScanSpec(events, pred)},
                            limit=int(rng.integers(5, 20)),
                            order_by=("events", "num_sightings", True)))
        else:
            qs.append(Query(scans={"events": TableScanSpec(events, pred)}))
    return qs


def _time(fn, repeats: int) -> float:
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


BLOOM_NDV_LIMIT = 64   # push every join build over the distinct limit


def make_bloom_queries(Q: int, events, users, rng):
    """All-join workload whose build sides exceed BLOOM_NDV_LIMIT: every
    summary is a blocked Bloom filter, isolating the Bloom matching path
    (ISSUE 3 — previously a per-query host fallback, now one batched
    enumeration launch per table group)."""
    qs = []
    for _ in range(Q):
        frac = float(np.exp(rng.normal(np.log(0.004), 1.0)))
        lo = TS_MAX * (1 - min(frac, 1.0))
        pred = (E.col("ts") >= lo) & (E.col("ts") <= TS_MAX)
        lo_a = int(rng.integers(20, 60))
        upred = (E.col("age") >= lo_a) & (E.col("age") <= lo_a + 14)
        qs.append(Query(
            scans={"events": TableScanSpec(events, pred),
                   "users": TableScanSpec(users, upred)},
            join=JoinSpec("users", "events", "id", "user_id")))
    return qs


def run_bloom_cell(P: int, Q: int, rng, repeats: int) -> dict:
    """Bloom-path qps: per-query host loop vs the batched engine."""
    events, users = tables(P)
    queries = make_bloom_queries(Q, events, users, rng)

    host_pipe = PruningPipeline(join_ndv_limit=BLOOM_NDV_LIMIT)
    sample = queries[:min(Q, LOOP_SAMPLE)]

    def loop():
        for q in sample:
            host_pipe.run(q)

    loop()
    s_loop = _time(loop, repeats) / len(sample)

    # verdict cache off: this cell pins the batched-Bloom join path, not
    # repeated-traffic caching (the verdict cell measures that)
    svc = PruningService(mode="ref", verdict_cache=False)
    pipe = PruningPipeline(filter_mode="device", service=svc,
                           join_ndv_limit=BLOOM_NDV_LIMIT)

    def batched():
        svc.run_batch(queries, pipe)

    counters = svc.run_batch(queries, pipe)[0].counters   # warm + snapshot
    s_batched = _time(batched, repeats)
    tech = counters["technique"]
    return dict(
        P=P, Q=Q,
        us_per_query_loop=s_loop * 1e6,
        us_total_batched=s_batched * 1e6,
        qps_loop=1.0 / s_loop,
        qps_batched=Q / s_batched,
        qps_delta=Q / s_batched - 1.0 / s_loop,
        speedup=(Q / s_batched) * s_loop,
        bloom_launches=tech.get("join_bloom", {}).get("launches", 0),
        bloom_fallbacks=tech.get("join_bloom", {}).get("fallbacks", 0),
    )


INGEST_ROUNDS = 8
INGEST_DP = 64        # partitions appended per ingest flush


def _ingest_table(P: int, rng) -> Table:
    return Table.build("ingest_events", {
        "ts": np.sort(rng.integers(0, TS_MAX, P)).astype(np.int64),
        "user_id": rng.integers(0, 50_000, P).astype(np.int64),
        "num_sightings": rng.integers(0, 1000, P).astype(np.int64),
    }, rows_per_partition=1)


def _ingest_flush(rng, n: int) -> dict:
    return {
        "ts": (TS_MAX + rng.integers(0, 10_000, n)).astype(np.int64),
        "user_id": rng.integers(0, 50_000, n).astype(np.int64),
        "num_sightings": rng.integers(0, 1000, n).astype(np.int64),
    }


def _ingest_queries(table, rng, q=16):
    qs = []
    for _ in range(q):
        frac = float(np.exp(rng.normal(np.log(0.004), 1.0)))
        lo = TS_MAX * (1 - min(frac, 1.0))
        qs.append(Query(scans={"ingest_events": TableScanSpec(
            table, (E.col("ts") >= lo) & (E.col("user_id") >= 1000))}))
    return qs


def run_ingest_cell(P: int, rounds: int = INGEST_ROUNDS,
                    d_p: int = INGEST_DP) -> dict:
    """Ingest churn (ISSUE 4): staging work per append round.

    A streaming workload appends ΔP micro-partitions to a resident
    P-partition table, queries, repeats.  The delta engine stages only
    the ``[C, ΔP]`` columns into the capacity-padded planes; the
    restage regime (the pre-ISSUE-4 behavior, emulated by invalidating
    the plane before each batch) pays a whole-plane staging every
    round.  Its per-round bytes are accounted as the *dense* ``[C, P]``
    plane the old code staged — capacity padding is new, so charging
    the padded size to the baseline would flatter the ratio.  The cell
    reports staged bytes and wall time per round for both.
    """
    def drive(restage: bool):
        rng = np.random.default_rng(3)
        table = _ingest_table(P, rng)
        # verdict cache off: the cell isolates [C, ΔP] stat-plane staging
        svc = PruningService(mode="ref", verdict_cache=False)
        pipe = PruningPipeline(filter_mode="device", service=svc)
        svc.run_batch(_ingest_queries(table, rng), pipe)   # warm staging
        bytes_rounds, times = [], []
        for _ in range(rounds):
            table.append_partitions(_ingest_flush(rng, d_p),
                                    rows_per_partition=1)
            if restage:
                svc.cache.invalidate(table.name)
            qs = _ingest_queries(table, rng)
            before = svc.cache.staging_snapshot()
            t0 = time.perf_counter()
            svc.run_batch(qs, pipe)
            times.append(time.perf_counter() - t0)
            after = svc.cache.staging_snapshot()
            if restage:   # dense [C, P] x 3 planes x f32: the old cost
                bytes_rounds.append(
                    3 * len(table.columns) * table.num_partitions * 4)
            else:
                bytes_rounds.append(
                    after["staged_bytes"] - before["staged_bytes"])
        snap = svc.cache.staging_snapshot()
        return (float(np.mean(bytes_rounds)), float(np.median(times)),
                snap["delta_stages"], snap["full_restages"])

    bytes_delta, s_delta, n_delta, n_full = drive(restage=False)
    bytes_full, s_full, _, _ = drive(restage=True)
    return dict(
        P=P, rounds=rounds, delta_partitions=d_p,
        bytes_per_round_delta=bytes_delta,
        bytes_per_round_restage=bytes_full,
        bytes_ratio=bytes_delta / bytes_full if bytes_full else None,
        us_per_round_delta=s_delta * 1e6,
        us_per_round_restage=s_full * 1e6,
        staging_speedup=s_full / s_delta if s_delta else None,
        delta_stages=n_delta, full_restages=n_full,
    )


FLEET_TABLES = 64
FLEET_ROUNDS = 6
FLEET_Q = 64
FLEET_BUDGET_FRAC = 0.25


def _fleet_tables(n_tables: int, rng) -> list:
    return [Table.build(f"fleet_{i:03d}", {
        "ts": np.sort(rng.integers(0, 100_000, 240)).astype(np.int64),
        "user_id": rng.integers(0, 5_000, 240).astype(np.int64),
        "num_sightings": rng.integers(0, 1_000, 240).astype(np.int64),
    }, rows_per_partition=10) for i in range(n_tables)]


def _fleet_batches(tables, rng, rounds: int, q: int) -> list:
    """Skewed-popularity rounds; popularity flips mid-run (churn)."""
    w = 1.0 / np.arange(1, len(tables) + 1) ** 2.0
    pop = w / w.sum()
    batches = []
    for rnd in range(rounds):
        if rnd == rounds // 2:
            pop = pop[::-1].copy()
        qs = []
        for _ in range(q):
            t = tables[int(rng.choice(len(tables), p=pop))]
            lo = int(rng.integers(0, 90_000))
            if rng.random() < 0.25:
                qs.append(Query(
                    scans={t.name: TableScanSpec(t, E.col("ts") >= lo)},
                    limit=5, order_by=(t.name, "num_sightings", True)))
            else:
                qs.append(Query(scans={t.name: TableScanSpec(
                    t, (E.col("ts") >= lo) & (E.col("ts") <= lo + 8_000))}))
        batches.append(qs)
    return batches


def run_fleet_cell(n_tables: int = FLEET_TABLES, rounds: int = FLEET_ROUNDS,
                   q: int = FLEET_Q,
                   budget_frac: float = FLEET_BUDGET_FRAC) -> dict:
    """Fleet churn (ISSUE 5): many tables under a tight HBM budget.

    The unbounded engine stages every table's planes once and keeps them
    all; the budgeted engine serves the same skewed workload from
    ``budget_frac`` of that working set, evicting and re-staging as
    popularity shifts.  The cell reports the qps cost of the churn, the
    eviction counters, and whether output stayed bit-identical — the
    fleet claim is only real if a bounded plane store serves unbounded
    tables correctly.
    """
    rng = np.random.default_rng(17)
    tables = _fleet_tables(n_tables, rng)
    batches = _fleet_batches(tables, rng, rounds, q)

    # Each regime runs the workload twice and the SECOND pass is timed:
    # pass 1 absorbs jit compiles and first-touch staging, so the
    # unbounded number is pure query cost (everything resident) and the
    # budgeted number is query cost + the steady-state eviction/restage
    # churn a 25% budget keeps paying — their ratio is the churn cost.
    # verdict cache off: the timed second pass repeats the same batches,
    # which verdict hits would serve without touching the stat planes —
    # this cell pins the eviction/restage economics of those planes
    unbounded = PruningService(mode="ref", verdict_cache=False)
    pipe_u = PruningPipeline(filter_mode="device", service=unbounded)
    unbounded.run_fleet(batches, pipe_u)
    working_set = unbounded.cache.resident_bytes
    budget = int(working_set * budget_frac)
    t0 = time.perf_counter()
    reps_u = unbounded.run_fleet(batches, pipe_u)
    s_unbounded = time.perf_counter() - t0

    budgeted = PruningService(mode="ref", budget_bytes=budget,
                              verdict_cache=False)
    pipe_b = PruningPipeline(filter_mode="device", service=budgeted)
    budgeted.run_fleet(batches, pipe_b)
    before = budgeted.cache.memory.snapshot()
    t0 = time.perf_counter()
    reps_b = budgeted.run_fleet(batches, pipe_b)
    s_budgeted = time.perf_counter() - t0
    mem = budgeted.cache.memory
    timed = {k: getattr(mem, k) - before[k]
             for k in ("evictions", "restage_storms", "hits", "misses")}

    def _same(a, b):
        for n in a.scan_sets:
            if not (np.array_equal(a.scan_sets[n].part_ids,
                                   b.scan_sets[n].part_ids)
                    and np.array_equal(a.scan_sets[n].match,
                                       b.scan_sets[n].match)):
                return False
        if (a.topk is None) != (b.topk is None):
            return False
        if a.topk is not None:          # 25% of the workload is top-k
            return (np.array_equal(a.topk.values, b.topk.values)
                    and np.array_equal(a.topk.skipped, b.topk.skipped))
        return True

    identical = all(_same(a, b)
                    for ru, rb in zip(reps_u, reps_b)
                    for a, b in zip(ru, rb))
    n_q = rounds * q
    return dict(
        tables=n_tables, rounds=rounds, q_per_round=q,
        working_set_bytes=working_set, budget_bytes=budget,
        qps_unbounded=n_q / s_unbounded, qps_budgeted=n_q / s_budgeted,
        churn_cost=s_budgeted / s_unbounded,
        bit_identical=bool(identical),
        evictions=timed["evictions"], restage_storms=timed["restage_storms"],
        plane_hits=timed["hits"], plane_misses=timed["misses"],
        peak_bytes=mem.peak_bytes,
        over_budget_events=mem.over_budget_events,
        budget_held=bool(mem.peak_bytes <= budget
                         and mem.over_budget_events == 0),
    )


VERDICT_POOL = 40     # distinct predicates in the repeated pool
VERDICT_ROUNDS = 7    # timed cache-on rounds: the cold round misses, and
                      # zipf-tail singletons are only admitted on their
                      # second sighting (doorkeeper), so the run-wide hit
                      # ratio needs a few rounds of headroom over 0.8
VERDICT_DP = 64       # partitions appended by the delta-repair phase
VERDICT_NOREP_ROUNDS = 2


def _verdict_table(P: int, rng) -> Table:
    """Dedicated events-shaped table (the repair phase appends to it)."""
    return Table.build("verdict_events", {
        "ts": np.sort(rng.integers(0, TS_MAX, P)).astype(np.int64),
        "user_id": rng.integers(0, 500_000, P).astype(np.int64),
        "num_sightings": rng.integers(0, 100_000, P).astype(np.int64),
    }, rows_per_partition=1)


def make_zipf_queries(Q: int, table, rng, pool: int = VERDICT_POOL):
    """Zipf-skewed repeated filter traffic over a fixed predicate pool —
    the dashboard / pinned-report shape the verdict cache targets."""
    preds = []
    for _ in range(pool):
        frac = float(np.exp(rng.normal(np.log(0.004), 1.0)))
        lo = TS_MAX * (1 - min(frac, 1.0))
        preds.append((E.col("ts") >= lo) & (E.col("ts") <= TS_MAX)
                     & (E.col("user_id") >= 1000))
    w = 1.0 / np.arange(1, pool + 1) ** 1.2
    picks = rng.choice(pool, size=Q, p=w / w.sum())
    return [Query(scans={table.name: TableScanSpec(table, preds[int(i)])})
            for i in picks]


def make_unique_queries(Q: int, table, rng, batch: int):
    """No-repetition traffic: every predicate canonically distinct,
    within the batch and across batches (disjoint literal bands)."""
    los = rng.permutation(TS_MAX // 2 + (batch * Q + np.arange(Q)) * 1000)
    return [Query(scans={table.name: TableScanSpec(
        table, (E.col("ts") >= int(lo)) & (E.col("user_id") >= 1000))})
        for lo in los]


def run_verdict_cell(P: int, Q: int, rng, repeats: int) -> dict:
    """Verdict-cache cell (ISSUE 9): zipf repeated traffic, cache-on vs
    cache-off qps with the hit/miss/repair counters; a no-repetition
    workload bounds the cache's miss-path overhead; a delta-repair phase
    shows appends patch resident verdict rows instead of relaunching."""
    table = _verdict_table(P, rng)
    queries = make_zipf_queries(Q, table, rng)

    def drive(cache_on: bool, rounds: int):
        svc = PruningService(mode="ref", verdict_cache=cache_on)
        pipe = PruningPipeline(filter_mode="device", service=svc)
        svc.run_batch(queries, pipe)          # warm: staging + cold misses
        return svc, _time(lambda: svc.run_batch(queries, pipe), rounds)

    svc_on, s_on = drive(True, max(repeats, VERDICT_ROUNDS - 1))
    _svc_off, s_off = drive(False, repeats)
    res = svc_on.resilience
    hits, misses = res["verdict_hits"], res["verdict_misses"]
    hit_ratio = hits / max(hits + misses, 1)

    # No-repetition traffic: every batch all-miss, so the cache only adds
    # its canonicalization + record overhead to the ordinary launch path.
    def unique_drive(cache_on: bool):
        svc = PruningService(mode="ref", verdict_cache=cache_on)
        pipe = PruningPipeline(filter_mode="device", service=svc)
        u_rng = np.random.default_rng(23)
        batches = [make_unique_queries(Q, table, u_rng, batch=i)
                   for i in range(VERDICT_NOREP_ROUNDS + 1)]
        svc.run_batch(batches[0], pipe)       # warm jits + stat planes
        t0 = time.perf_counter()
        for b in batches[1:]:
            svc.run_batch(b, pipe)
        return time.perf_counter() - t0

    s_u_on = unique_drive(True)
    s_u_off = unique_drive(False)

    # Delta repair: appends patch resident verdict rows in place — the
    # repeated batch stays a full hit, zero kernel launches.
    launches_before = svc_on.counters.launches
    table.append_partitions({
        "ts": (TS_MAX - rng.integers(0, 10_000, VERDICT_DP))
        .astype(np.int64),
        "user_id": rng.integers(0, 500_000, VERDICT_DP).astype(np.int64),
        "num_sightings": rng.integers(0, 100_000, VERDICT_DP)
        .astype(np.int64),
    }, rows_per_partition=1)
    pipe_on = PruningPipeline(filter_mode="device", service=svc_on)
    svc_on.run_batch(queries, pipe_on)

    return dict(
        P=P, Q=Q, pool=VERDICT_POOL,
        us_total_cached=s_on * 1e6,
        us_total_uncached=s_off * 1e6,
        qps_cached=Q / s_on,
        qps_uncached=Q / s_off,
        speedup=s_off / s_on,
        hit_ratio=hit_ratio,
        verdict_hits=hits,
        verdict_misses=misses,
        verdict_deduped=res["verdict_deduped"],
        verdict_repairs=svc_on.cache.integrity["verdict_repairs"],
        repair_launches=svc_on.counters.launches - launches_before,
        norep_qps_ratio=s_u_off / s_u_on,
    )


RES_TABLES = 24
RES_ROUNDS = 4
RES_Q = 48


def run_resilience_cell(n_tables: int = RES_TABLES,
                        rounds: int = RES_ROUNDS, q: int = RES_Q) -> dict:
    """No-fault overhead of the resilience layer (ISSUE 6).

    Every launch now runs through the degradation ladder and every plane
    read sits on the sampled checksum schedule; with no injector and
    nothing failing, both must be bookkeeping — this cell times the same
    fleet workload with verification off (``integrity_sample=0``, the
    closest stand-in for the pre-resilience engine) vs the shipping
    default (every 64th read verified), and asserts the ladder stayed on
    its top rung throughout (zero demotions / retries / passthroughs).
    """
    rng = np.random.default_rng(31)
    tables = _fleet_tables(n_tables, rng)
    batches = _fleet_batches(tables, rng, rounds, q)

    def timed(**kw):
        # verdict cache off: the ladder/verification overhead must be
        # measured on real launches, not repeated-batch verdict hits
        svc = PruningService(mode="ref", verdict_cache=False, **kw)
        pipe = PruningPipeline(filter_mode="device", service=svc)
        svc.run_fleet(batches, pipe)        # warm jits + planes
        t0 = time.perf_counter()
        svc.run_fleet(batches, pipe)
        return svc, time.perf_counter() - t0

    _bare, s_bare = timed(integrity_sample=0)
    resilient, s_res = timed()              # default sampled verification

    res = resilient.fleet_summary()["resilience"]
    integ = resilient.cache.integrity_snapshot()
    n_q = rounds * q
    return dict(
        tables=n_tables, rounds=rounds, q_per_round=q,
        qps_baseline=n_q / s_bare, qps_resilient=n_q / s_res,
        overhead=s_res / s_bare - 1.0,
        demotions=sum(res["demotions"].values()),
        retries=res["retries"], passthroughs=res["passthroughs"],
        verifications=integ["verifications"],
        checksum_failures=integ["checksum_failures"],
    )


SLO_BATCH_CAP = 16          # front-end micro-batch size cap Q
SLO_LOAD_FRACS = (0.25, 0.5, 0.75, 0.9, 1.0, 1.1)
SLO_SERVICE_MULT = 4.0      # SLO = deadline + this many batch services


def _reports_equal(a, b) -> bool:
    """Bit-identical pruning outcome: same scan sets, same top-k rows."""
    if set(a.scan_sets) != set(b.scan_sets):
        return False
    for n in a.scan_sets:
        if not (np.array_equal(a.scan_sets[n].part_ids,
                               b.scan_sets[n].part_ids)
                and np.array_equal(a.scan_sets[n].match,
                                   b.scan_sets[n].match)):
            return False
    if (a.topk is None) != (b.topk is None):
        return False
    if a.topk is not None:
        return (np.array_equal(a.topk.values, b.topk.values)
                and np.array_equal(a.topk.skipped, b.topk.skipped))
    return True


def run_slo_cell(P: int, Q: int, rng) -> dict:
    """Serving-SLO cell (ISSUE 10): offered-load sweep to the p99 knee.

    Baseline: synchronous ``run_batch`` over the workload in B-sized
    chunks — the throughput ceiling the async front-end must track.
    Sweep: open-loop arrivals paced at fractions of that ceiling through
    a threaded ``ServingFrontend`` (deadline sized to ~1.5 batch fill
    times, so the size cap fires under load and the deadline bounds the
    tail when traffic is sparse).  The knee is the highest offered load
    whose measured p99 still meets the SLO; "qps under SLO" is the
    achieved throughput there.  A manual-mode front-end also replays the
    workload as one size-capped batch to pin bit-identical parity with
    direct ``run_batch``.
    """
    events, users = tables(P)
    queries = make_queries(Q, events, users, rng)
    B = min(Q, SLO_BATCH_CAP)
    svc = PruningService(mode="ref", verdict_cache=False)
    pipe = PruningPipeline(filter_mode="device", service=svc)
    chunks = [queries[i:i + B] for i in range(0, Q, B)]

    def sync():
        for c in chunks:
            svc.run_batch(c, pipe)

    sync()                                    # warm jits + planes
    s_sync = _time(sync, 1)
    qps_sync = Q / s_sync
    batch_s = s_sync / len(chunks)
    deadline_s = 1.5 * B / qps_sync
    slo_ms = (deadline_s + SLO_SERVICE_MULT * batch_s) * 1e3

    # Parity: one size-capped manual dispatch vs direct run_batch.
    direct = svc.run_batch(queries, pipe)
    with ServingFrontend(svc, pipe, max_batch=Q, deadline_s=60.0,
                         threaded=False) as fe:
        futs = [fe.submit(q) for q in queries]   # Q-th submit dispatches
    identical = all(_reports_equal(f.result().report, d)
                    for f, d in zip(futs, direct))

    levels = []
    for frac in SLO_LOAD_FRACS:
        rate = qps_sync * frac
        before = dict(svc.latency)
        fe = ServingFrontend(svc, pipe, max_batch=B, deadline_s=deadline_s)
        futs = []
        t0 = time.monotonic()
        for i, q in enumerate(queries):
            lag = t0 + i / rate - time.monotonic()
            if lag > 0:
                time.sleep(lag)
            futs.append(fe.submit(q))
        fe.drain()
        s_level = time.monotonic() - t0
        fe.close()
        lats = np.asarray([f.result().latency_ms for f in futs])
        p50, p99 = np.percentile(lats, (50.0, 99.0))
        levels.append(dict(
            offered_frac=frac, offered_qps=rate,
            achieved_qps=Q / s_level,
            p50_ms=float(p50), p99_ms=float(p99),
            max_ms=float(lats.max()),
            deadline_fired=svc.latency["deadline_fired"]
            - before["deadline_fired"],
            size_fired=svc.latency["size_fired"] - before["size_fired"],
            flush_fired=svc.latency["flush_fired"] - before["flush_fired"],
        ))
    under = [lv for lv in levels if lv["p99_ms"] <= slo_ms]
    knee = max(under, key=lambda lv: lv["achieved_qps"]) if under else None
    return dict(
        P=P, Q=Q, batch=B,
        deadline_ms=deadline_s * 1e3, slo_ms=slo_ms,
        qps_sync=qps_sync,
        levels=levels,
        knee_offered_frac=knee["offered_frac"] if knee else None,
        knee_p99_ms=knee["p99_ms"] if knee else None,
        qps_under_slo=knee["achieved_qps"] if knee else 0.0,
        frontend_identical=bool(identical),
        prefetch_stages=svc.cache.staging_snapshot()["prefetch_stages"],
    )


def run(grid_p=GRID_P, grid_q=GRID_Q, csv: bool = True,
        json_path: str = "BENCH_runtime_prune.json"):
    rng = np.random.default_rng(0)
    rows, cells = [], []
    for P in grid_p:
        events, users = tables(P)
        repeats = 3 if P <= 10_000 else 1
        for Q in grid_q:
            queries = make_queries(Q, events, users, rng)

            # Regime A — per-query host loop (full pipelines, host mode).
            host_pipe = PruningPipeline()
            sample = queries[:min(Q, LOOP_SAMPLE)]

            def loop():
                for q in sample:
                    host_pipe.run(q)

            loop()                            # warm numpy/dispatch caches
            s_loop = _time(loop, repeats) / len(sample)   # sec per query
            qps_loop = 1.0 / s_loop

            # Regime B — batched engine: all device-eligible stages packed
            # per table group against resident planes.  Verdict cache off:
            # the timing loop repeats one batch, which verdict hits would
            # serve without a single launch — this grid pins the launch
            # amortization claim (the verdict cell measures caching).
            svc = PruningService(mode="ref", verdict_cache=False)
            pipe = PruningPipeline(filter_mode="device", service=svc)

            def batched():
                svc.run_batch(queries, pipe)

            # warm jit caches + planes; the warm-up reports already carry
            # this workload's per-batch counter delta (launches repeat
            # identically every batch — only staging is cached)
            stage_launches = svc.run_batch(queries, pipe)[0].counters[
                "technique"]
            s_batched = _time(batched, repeats)
            qps_batched = Q / s_batched

            cell = dict(
                P=P, Q=Q,
                us_per_query_loop=s_loop * 1e6,
                us_total_batched=s_batched * 1e6,
                qps_loop=qps_loop,
                qps_batched=qps_batched,
                speedup=qps_batched / qps_loop,
                launches=stage_launches,
            )
            cells.append(cell)
            rows.append((
                f"runtime_prune_P{P}_Q{Q}",
                s_batched * 1e6,
                f"qps_batched={qps_batched:.0f} qps_loop={qps_loop:.0f} "
                f"x{cell['speedup']:.1f}",
            ))
    # Bloom-path cell (ISSUE 3): the biggest grid P, all-Bloom joins —
    # reports the qps delta now that the enumeration is one batched
    # launch per table group instead of a per-query host fallback.
    bloom_cell = run_bloom_cell(max(grid_p), max(min(grid_q), 32), rng,
                                repeats=3 if max(grid_p) <= 10_000 else 1)
    rows.append((
        f"runtime_prune_bloom_P{bloom_cell['P']}_Q{bloom_cell['Q']}",
        bloom_cell["us_total_batched"],
        f"qps_batched={bloom_cell['qps_batched']:.0f} "
        f"qps_loop={bloom_cell['qps_loop']:.0f} "
        f"x{bloom_cell['speedup']:.1f}",
    ))
    # Ingest-churn cell (ISSUE 4): staging work per streaming append —
    # delta-staged planes vs the old restage-per-DML behavior.
    ingest_cell = run_ingest_cell(min(max(grid_p), 20_000))
    rows.append((
        f"runtime_prune_ingest_P{ingest_cell['P']}_dP"
        f"{ingest_cell['delta_partitions']}",
        ingest_cell["us_per_round_delta"],
        f"staged {ingest_cell['bytes_per_round_delta']:.0f}B/round vs "
        f"{ingest_cell['bytes_per_round_restage']:.0f}B restaged "
        f"(x{1 / max(ingest_cell['bytes_ratio'], 1e-9):.0f} less)",
    ))
    # Fleet-churn cell (ISSUE 5): 64 tables under a 25% HBM budget —
    # eviction/restage economics of the LRU plane manager.
    fleet_cell = run_fleet_cell()
    rows.append((
        f"runtime_prune_fleet_T{fleet_cell['tables']}_"
        f"b{int(FLEET_BUDGET_FRAC * 100)}pct",
        1e6 * fleet_cell["rounds"] * fleet_cell["q_per_round"]
        / fleet_cell["qps_budgeted"],
        f"qps {fleet_cell['qps_budgeted']:.0f} vs unbounded "
        f"{fleet_cell['qps_unbounded']:.0f} | {fleet_cell['evictions']} "
        f"evictions, {fleet_cell['restage_storms']} storms, "
        f"identical={fleet_cell['bit_identical']}",
    ))
    # Verdict-cache cell (ISSUE 9): zipf repeated traffic served from
    # device-resident verdict rows vs relaunching every batch, plus the
    # no-repetition overhead bound and the append-repair counters.
    verdict_cell = run_verdict_cell(max(grid_p), max(grid_q), rng,
                                    repeats=3 if max(grid_p) <= 10_000
                                    else 1)
    rows.append((
        f"runtime_prune_verdict_P{verdict_cell['P']}_Q{verdict_cell['Q']}",
        verdict_cell["us_total_cached"],
        f"qps cached={verdict_cell['qps_cached']:.0f} vs "
        f"uncached={verdict_cell['qps_uncached']:.0f} "
        f"x{verdict_cell['speedup']:.1f} | hit {verdict_cell['hit_ratio']:.2f} "
        f"repairs {verdict_cell['verdict_repairs']} "
        f"norep x{verdict_cell['norep_qps_ratio']:.2f}",
    ))
    # Resilience cell (ISSUE 6): no-fault price of the degradation
    # ladder + sampled plane-checksum verification.
    resilience_cell = run_resilience_cell()
    rows.append((
        f"runtime_prune_resilience_T{resilience_cell['tables']}",
        1e6 * resilience_cell["rounds"] * resilience_cell["q_per_round"]
        / resilience_cell["qps_resilient"],
        f"qps {resilience_cell['qps_resilient']:.0f} vs bare "
        f"{resilience_cell['qps_baseline']:.0f} "
        f"(+{100 * resilience_cell['overhead']:.1f}%) | "
        f"{resilience_cell['verifications']} verifies, "
        f"{resilience_cell['demotions']} demotions",
    ))
    # Serving-SLO cell (ISSUE 10): async front-end offered-load sweep to
    # the p99 knee — the first end-to-end qps-under-SLO number for the
    # fleet path, plus bit-identical parity with direct run_batch.
    slo_cell = run_slo_cell(max(grid_p), max(grid_q), rng)
    knee_p99 = slo_cell["knee_p99_ms"]
    rows.append((
        f"runtime_prune_slo_P{slo_cell['P']}_Q{slo_cell['Q']}",
        1e6 * slo_cell["Q"] / max(slo_cell["qps_under_slo"], 1e-9),
        f"qps_under_slo={slo_cell['qps_under_slo']:.0f} vs "
        f"sync={slo_cell['qps_sync']:.0f} | "
        f"knee@{slo_cell['knee_offered_frac']} "
        f"p99={'-' if knee_p99 is None else f'{knee_p99:.2f}'}ms "
        f"(slo {slo_cell['slo_ms']:.2f}ms) "
        f"identical={slo_cell['frontend_identical']}",
    ))
    if csv:
        emit(rows)
    if json_path:
        accept = [c for c in cells if c["P"] == 100_000 and c["Q"] == 256]
        payload = dict(
            bench="runtime_prune",
            backend="ref",
            workload="mixed filter+join+topk",
            loop_sample=LOOP_SAMPLE,
            grid=cells,
            bloom=bloom_cell,
            ingest=ingest_cell,
            fleet=fleet_cell,
            resilience=resilience_cell,
            verdict=verdict_cell,
            slo=slo_cell,
            acceptance=dict(
                target="qps_batched >= 5x qps_loop at Q=256, P=100k",
                speedup=accept[0]["speedup"] if accept else None,
                # None (not False) when the acceptance cell isn't in the
                # grid — the BENCH_CI lane runs a small grid and must not
                # publish a spurious failure per PR.
                passed=(bool(accept[0]["speedup"] >= 5.0) if accept
                        else None),
                bloom_target=("batched Bloom path beats the per-query host "
                              "loop with zero host fallbacks"),
                bloom_qps_delta=bloom_cell["qps_delta"],
                bloom_passed=bool(bloom_cell["qps_delta"] > 0
                                  and bloom_cell["bloom_fallbacks"] == 0
                                  and bloom_cell["bloom_launches"] >= 1),
                ingest_target=("appending ΔP partitions stages O(ΔP) bytes: "
                               "delta staging < 10% of per-round restage, "
                               "no full restage in steady state"),
                ingest_bytes_ratio=ingest_cell["bytes_ratio"],
                ingest_passed=bool(ingest_cell["bytes_ratio"] is not None
                                   and ingest_cell["bytes_ratio"] < 0.10
                                   and ingest_cell["full_restages"] == 0),
                fleet_target=("64 tables under a 25% budget: output "
                              "bit-identical to the unbounded engine, "
                              "evictions > 0, budget never exceeded"),
                fleet_passed=bool(fleet_cell["bit_identical"]
                                  and fleet_cell["evictions"] > 0
                                  and fleet_cell["budget_held"]),
                resilience_target=("no-fault cost of the degradation "
                                   "ladder + sampled checksum "
                                   "verification < 5% qps, ladder never "
                                   "leaves its top rung"),
                resilience_overhead=resilience_cell["overhead"],
                resilience_overhead_ok=bool(
                    resilience_cell["overhead"] < 0.05),
                resilience_zero_demotions=bool(
                    resilience_cell["demotions"] == 0
                    and resilience_cell["retries"] == 0
                    and resilience_cell["passthroughs"] == 0
                    and resilience_cell["checksum_failures"] == 0),
                verdict_target=("zipf repeated traffic: cache-on >= 2x "
                                "cache-off qps at hit ratio >= 0.8; "
                                "appends repaired in place with zero "
                                "launches; no-repetition regression < 5%"),
                verdict_speedup=verdict_cell["speedup"],
                verdict_hit_ratio=verdict_cell["hit_ratio"],
                # None (not False) off the acceptance size: the BENCH_CI
                # lane's tiny cells amortize nothing, so a boolean there
                # would publish a spurious per-PR failure
                verdict_passed=(bool(
                    verdict_cell["speedup"] >= 2.0
                    and verdict_cell["hit_ratio"] >= 0.8
                    and verdict_cell["verdict_repairs"] >= 1
                    and verdict_cell["repair_launches"] == 0)
                    if (verdict_cell["P"], verdict_cell["Q"])
                    == (100_000, 256) else None),
                verdict_norep_ratio=verdict_cell["norep_qps_ratio"],
                verdict_norep_passed=(bool(
                    verdict_cell["norep_qps_ratio"] >= 0.95)
                    if (verdict_cell["P"], verdict_cell["Q"])
                    == (100_000, 256) else None),
                slo_target=("async front-end qps under the p99 SLO within "
                            "10% of synchronous run_batch qps at equal "
                            "batch size; results bit-identical"),
                slo_qps_under_slo=slo_cell["qps_under_slo"],
                slo_qps_sync=slo_cell["qps_sync"],
                slo_identical=slo_cell["frontend_identical"],
                # None off the acceptance size (BENCH_CI small grid):
                # tiny cells make thread-scheduling noise dominate the
                # knee, so a boolean there would publish spurious
                # per-PR failures
                slo_passed=(bool(
                    slo_cell["qps_under_slo"]
                    >= 0.9 * slo_cell["qps_sync"]
                    and slo_cell["frontend_identical"])
                    if (slo_cell["P"], slo_cell["Q"]) == (100_000, 256)
                    else None),
            ),
        )
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
    return rows, cells


def main():
    # BENCH_JSON_DIR is set by benchmarks/run.py from --json-dir; empty
    # means JSON emission is disabled.  Standalone runs default to CWD.
    json_dir = os.environ.get("BENCH_JSON_DIR", ".")
    json_path = (os.path.join(json_dir, "BENCH_runtime_prune.json")
                 if json_dir else "")
    if os.environ.get("BENCH_CI"):
        # CI artifact lane: a small grid that finishes in minutes but
        # still tracks the qps/staging trajectory per PR.
        run(grid_p=(2000,), grid_q=(8, 16), json_path=json_path)
    else:
        run(json_path=json_path)


if __name__ == "__main__":
    main()
