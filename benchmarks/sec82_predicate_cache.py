"""Sec. 8.2 (implemented extension): predicate caching for top-k vs
boundary pruning.

Reproduces the paper's qualitative analysis quantitatively:
  * on randomly-ordered data, a cache HIT scans only the contributing
    partitions — beating pruning (which needs the heap to saturate);
  * on (partially) sorted data, pruning alone is already near-optimal;
  * top-k plan shapes are barely repetitive (Fig. 12), so across a
    realistic plan-shape distribution the blended win of caching is
    modest — "both techniques should be implemented" (the paper's
    conclusion), which the combined row shows.
"""

from __future__ import annotations

import numpy as np

from repro.core.metadata import ScanSet
from repro.core.predicate_cache import PredicateCache, TableVersion, plan_key
from repro.core.prune_topk import run_topk
from repro.data.table import Table

from .common import emit, timeit


def _table(sorted_frac: float, n=40_000, rows_pp=200, seed=0) -> Table:
    rng = np.random.default_rng(seed)
    v = np.sort(rng.integers(0, 1_000_000, size=n))
    if sorted_frac < 1.0:
        sigma = (1 - sorted_frac) * n
        v = v[np.argsort(np.arange(n) + rng.normal(0, sigma, n))]
    return Table.build(
        "t",
        {"v": v.astype(np.int64),
         # selectivity column UNCORRELATED with v: the regime where
         # boundary pruning struggles (high-max partitions hold no
         # qualifying rows) and caching shines
         "flag": rng.integers(0, 100, size=n).astype(np.int64)},
        rows_per_partition=rows_pp)


def fig12_repetitions(rng, n_shapes=200):
    """Plan-shape repetition counts modeled on Fig. 12 (3-day window)."""
    reps = []
    for _ in range(n_shapes):
        u = rng.random()
        if u < 0.72:
            reps.append(1)
        elif u < 0.92:
            reps.append(int(rng.integers(2, 4)))
        else:
            reps.append(int(rng.integers(4, 30)))
    return reps


def run(csv: bool = True):
    from repro.core import expr as E
    from repro.core.metadata import NO_MATCH
    from repro.core.prune_filter import eval_tv

    rng = np.random.default_rng(0)
    rows = []
    for label, frac, pred in (
        ("random", 0.0, None),
        ("sorted", 0.98, None),
        ("random_filtered", 0.0, E.col("flag") < 2),   # 2% selectivity
    ):
        tbl = _table(frac)
        P = tbl.num_partitions
        if pred is None:
            scan = ScanSet.full(P)
        else:
            tv_ = eval_tv(pred, tbl.stats)
            keep = tv_ > NO_MATCH
            scan = ScanSet(np.where(keep)[0], tv_[keep])
        prune = run_topk(tbl, scan, "v", 10, pred=pred, strategy="sort")
        cached = len(prune.contributing)
        rows.append((f"sec82_prune_scans_{label}", 0.0,
                     f"pruning={len(prune.scanned)}/{P} cache_hit={cached}/{P}"))

    # blended over the Fig. 12 plan-shape distribution, in the
    # filtered-random regime where caching can win
    tbl = _table(0.0)
    P = tbl.num_partitions
    pred = E.col("flag") < 2
    tv_ = eval_tv(pred, tbl.stats)
    keep = tv_ > NO_MATCH
    base_scan = ScanSet(np.where(keep)[0], tv_[keep])
    cache = PredicateCache(max_entries=64)
    tv = TableVersion(P)
    scanned_prune_only = 0
    scanned_with_cache = 0
    for shape_id, reps in enumerate(fig12_repetitions(rng, n_shapes=60)):
        key = plan_key("t", repr(pred), "v", True, 10 + shape_id)
        for r in range(reps):
            base = run_topk(tbl, base_scan, "v", 10, pred=pred, strategy="sort")
            scanned_prune_only += len(base.scanned)
            hit = cache.lookup(key, tv)
            if hit is None:
                scanned_with_cache += len(base.scanned)
                cache.record(key, base.contributing, tv)
            else:
                res = run_topk(tbl, ScanSet(hit), "v", 10, pred=pred,
                               strategy="none")
                scanned_with_cache += len(res.scanned)
    us = timeit(lambda: run_topk(tbl, ScanSet.full(P), "v", 10,
                                 strategy="sort"))
    rows.append(("sec82_blended_fig12", us,
                 f"prune_only={scanned_prune_only} "
                 f"prune+cache={scanned_with_cache} "
                 f"hit_rate={cache.hit_rate:.2f} "
                 f"(paper: modest — plans rarely repeat)"))
    if csv:
        emit(rows)
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
