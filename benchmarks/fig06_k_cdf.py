"""Figure 6: CDF of k in LIMIT queries (OFFSET included).

Paper: 97% of queries have k <= 10,000; 99.9% have k <= 2,000,000;
the bulk at k in {0, 1}.
"""

from __future__ import annotations

import numpy as np

from repro.data.generator import sample_limit_k

from .common import emit, timeit


def run(n: int = 100_000, seed: int = 4, csv: bool = True):
    rng = np.random.default_rng(seed)
    ks = np.array([sample_limit_k(rng) for _ in range(n)])
    us = timeit(lambda: sample_limit_k(rng))
    rows = [
        ("fig06_p_k_le_1", us,
         f"measured={float((ks <= 1).mean()):.4f} (mass at 0/1)"),
        ("fig06_p_k_le_10000", us,
         f"measured={float((ks <= 10_000).mean()):.4f} paper=0.97"),
        ("fig06_p_k_le_2M", us,
         f"measured={float((ks <= 2_000_000).mean()):.4f} paper=0.999"),
    ]
    if csv:
        emit(rows)
    return ks


def main():
    run()


if __name__ == "__main__":
    main()
