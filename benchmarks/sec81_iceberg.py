"""Sec. 8.1: hierarchical (Iceberg manifest -> Parquet row-group) pruning
and metadata backfill.

Measures what the two-level layout saves: row-group stats touched per
query (an object-store round trip per file in a real lake), and the
one-off cost + subsequent benefit of backfilling files that arrived
without statistics.
"""

from __future__ import annotations

import numpy as np

from repro.core import expr as E
from repro.data.generator import make_events_table
from repro.data.iceberg import IcebergTable, two_level_prune

from .common import emit, timeit


def run(csv: bool = True):
    rng = np.random.default_rng(0)
    tbl = make_events_table(rng, n_rows=100_000, rows_per_partition=250)
    G = tbl.num_partitions
    pred = E.col("ts") >= 9_500_000

    ice = IcebergTable.from_table(tbl, groups_per_file=16)
    res = two_level_prune(pred, ice)
    us = timeit(lambda: two_level_prune(pred, ice))
    rows = [
        ("sec81_two_level_meta_reads", us,
         f"file={res.file_meta_reads} rowgroup={res.group_meta_reads} "
         f"vs flat={G} ({1 - (res.file_meta_reads + res.group_meta_reads) / G:.1%} fewer)"),
        ("sec81_files_pruned", us, f"{res.files_pruned}/{ice.num_files}"),
    ]

    # backfill: 25% of files arrive without stats
    missing = np.arange(0, ice.num_files, 4)
    ice2 = IcebergTable.from_table(tbl, groups_per_file=16,
                                   missing_meta_files=missing)
    before = two_level_prune(pred, ice2)
    cost = sum(ice2.backfill(int(f)) for f in missing)
    after = two_level_prune(pred, ice2)
    rows.append((
        "sec81_backfill", us,
        f"rowgroup_reads {before.group_meta_reads}->{after.group_meta_reads} "
        f"after backfilling {len(missing)} files ({cost} rows scanned once)"))
    if csv:
        emit(rows)
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
