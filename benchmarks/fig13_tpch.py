"""Figure 13 / Sec. 8.3: TPC-H-like pruning vs. the production-like mix.

Paper: TPC-H SF100 clustered on l_shipdate/o_orderdate averages a 28.7%
pruning ratio (median per-query 8.3%) — an order of magnitude below the
99.4% production figure, because TPC-H predicates are far less selective.
We reproduce representative TPC-H predicate shapes (Q1/Q3/Q6-style date
windows, quantity/discount bands) on correspondingly clustered tables.
"""

from __future__ import annotations

import numpy as np

from repro.core import expr as E
from repro.core.flow import JoinSpec, PruningPipeline, Query, TableScanSpec
from repro.data.generator import DATE_HI, DATE_LO, make_lineitem, make_orders

from .common import dist_stats, emit, timeit

_CACHE = {}


def tpch_tables(seed=9):
    if seed not in _CACHE:
        rng = np.random.default_rng(seed)
        _CACHE[seed] = (make_lineitem(rng, n_rows=200_000),
                        make_orders(rng, n_rows=50_000))
    return _CACHE[seed]


def tpch_queries(lineitem, orders, rng):
    """Representative TPC-H predicate shapes (date windows dominate)."""
    span = DATE_HI - DATE_LO
    qs = []
    # Q1: l_shipdate <= DATE - [60..120] days  (scans ~97% of the table)
    delta = int(rng.integers(60, 120))
    qs.append(Query(scans={"lineitem": TableScanSpec(
        lineitem, E.col("l_shipdate") <= DATE_HI - delta)}))
    # Q6: one-year shipdate window + discount band + quantity cap
    y0 = DATE_LO + int(rng.integers(0, 5)) * 365
    qs.append(Query(scans={"lineitem": TableScanSpec(
        lineitem,
        (E.col("l_shipdate") >= y0) & (E.col("l_shipdate") < y0 + 365)
        & (E.col("l_discount") >= 0.05) & (E.col("l_discount") <= 0.07)
        & (E.col("l_quantity") < 24))}))
    # Q3-style: orders before a date joined to lineitem after it
    cut = DATE_LO + int(rng.integers(200, span - 200))
    qs.append(Query(
        scans={
            "orders": TableScanSpec(orders, E.col("o_orderdate") < cut),
            "lineitem": TableScanSpec(lineitem, E.col("l_shipdate") > cut),
        },
        join=JoinSpec("orders", "lineitem", "o_orderkey", "l_orderkey"),
    ))
    # Q12-style: one-year receipt window
    y1 = DATE_LO + int(rng.integers(0, 5)) * 365
    qs.append(Query(scans={"lineitem": TableScanSpec(
        lineitem,
        (E.col("l_shipdate") >= y1) & (E.col("l_shipdate") < y1 + 365))}))
    # returnflag scan (unprunable: 3 values in every partition)
    qs.append(Query(scans={"lineitem": TableScanSpec(
        lineitem, E.col("l_returnflag") == E.lit("R-00000"))}))
    # roughly half of TPC-H's 22 queries carry no lineitem/orders-prunable
    # predicate at all (Q2/Q9/Q11/Q13/Q16/Q18/Q22 shapes) — full scans:
    for _ in range(3):
        qs.append(Query(scans={"lineitem": TableScanSpec(lineitem, E.true())}))
    qs.append(Query(scans={"orders": TableScanSpec(orders, E.true())}))
    # Q4-style: quarter window on orders + EXISTS-ish lineitem full scan
    q0 = DATE_LO + int(rng.integers(0, 24)) * 91
    qs.append(Query(scans={
        "orders": TableScanSpec(
            orders, (E.col("o_orderdate") >= q0)
            & (E.col("o_orderdate") < q0 + 91)),
        "lineitem": TableScanSpec(lineitem, E.true()),
    }))
    return qs


def run(rounds: int = 6, seed: int = 9, csv: bool = True):
    rng = np.random.default_rng(seed)
    lineitem, orders = tpch_tables(seed)
    pipe = PruningPipeline()
    per_query = []
    total_parts = total_after = 0
    for _ in range(rounds):
        for q in tpch_queries(lineitem, orders, rng):
            rep = pipe.run(q)
            per_query.append(rep.overall_ratio)
            total_parts += sum(s.table.num_partitions
                               for s in rep._scan_specs.values())
            total_after += sum(len(ss) for ss in rep.scan_sets.values())
    avg = 1.0 - total_after / total_parts
    med = float(np.median(per_query))
    us = timeit(lambda: pipe.run(tpch_queries(lineitem, orders, rng)[1]))
    rows = [
        ("fig13_tpch_avg_pruning", us, f"{avg:.3f} (paper 0.287)"),
        ("fig13_tpch_median_query", us, f"{med:.3f} (paper 0.083)"),
        ("fig13_tpch_dist", us, dist_stats(per_query)),
    ]
    if csv:
        emit(rows)
    return per_query, avg


def main():
    run()


if __name__ == "__main__":
    main()
