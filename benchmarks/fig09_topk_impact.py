"""Figure 9: top-k pruning ratio at table-scan level + runtime improvement,
bucketed by baseline execution cost.

Paper: average pruning ratio ~77% where applied; runtime-improvement CDFs
track the pruning-ratio CDFs closely.  Wall-clock on a laptop CPU is
noise-dominated, so 'runtime' uses the executor's bytes-scanned cost model
(the quantity network-bound scans pay for) and we report the correlation.
"""

from __future__ import annotations

import numpy as np

from repro.core.flow import PruningPipeline
from repro.data.scan import execute_query

from .common import dist_stats, emit, timeit
from .workload import sample_topk_query, tables


def run(n: int = 30, seed: int = 6, csv: bool = True):
    rng = np.random.default_rng(seed)
    events, _ = tables(seed)
    pipe = PruningPipeline()
    ratios, improvements = [], []
    for _ in range(n):
        q = sample_topk_query(rng, events)
        rep = pipe.run(q)
        r = rep.per_scan["events"].get("topk")
        # paper population: scans where top-k pruning was SUCCESSFULLY
        # applied (it skipped at least one partition)
        if not (r and r.applied and r.before > 1 and r.ratio > 0):
            continue
        ratios.append(r.ratio)
        pruned = execute_query(q, rep)
        base = execute_query(q, None)
        improvements.append(1.0 - pruned.total_bytes() / base.total_bytes())
    corr = float(np.corrcoef(ratios, improvements)[0, 1]) if len(ratios) > 2 else 0.0
    us = timeit(lambda: pipe.run(sample_topk_query(rng, events)))
    rows = [
        ("fig09_pruning_ratio", us, dist_stats(ratios) + " (paper mean ~0.77)"),
        ("fig09_io_improvement", us, dist_stats(improvements)),
        ("fig09_ratio_io_corr", us,
         f"{corr:.3f} (paper: distributions track closely)"),
    ]
    if csv:
        emit(rows)
    return ratios, improvements


def main():
    run()


if __name__ == "__main__":
    main()
