"""Figure 8: influence of partition processing order on top-k pruning.

Paper: sorting by block max improves both the median and the tails vs. a
random order, on eligible queries (>= 1s baseline runtime — here: tables
large enough that the scan dominates).
"""

from __future__ import annotations

import numpy as np

from repro.core.flow import PruningPipeline

from .common import dist_stats, emit, timeit
from .workload import sample_topk_query, tables


def run(n: int = 40, seed: int = 5, csv: bool = True):
    rng = np.random.default_rng(seed)
    events, _ = tables(seed)
    out = {}
    for strategy in ("none", "random", "sort"):
        pipe = PruningPipeline(topk_strategy=strategy, topk_upfront_init=False)
        rng_s = np.random.default_rng(seed)  # identical query stream
        ratios = []
        for _ in range(n):
            q = sample_topk_query(rng_s, events)
            rep = pipe.run(q)
            r = rep.per_scan["events"].get("topk")
            # eligible population = the paper's ">= 1s baseline" proxy:
            # scans still large after the earlier pruning stages
            if r and r.applied and r.before >= 50:
                ratios.append(r.ratio)
        out[strategy] = ratios
    pipe = PruningPipeline(topk_strategy="sort")
    us = timeit(lambda: pipe.run(sample_topk_query(
        np.random.default_rng(0), events)))
    rows = [(f"fig08_{k}", us, dist_stats(v)) for k, v in out.items()]
    means = {k: float(np.mean(v)) for k, v in out.items() if v}
    rows.append(("fig08_sort_vs_random_delta", us,
                 f"{means.get('sort', 0) - means.get('random', 0):+.3f} "
                 "(paper: positive, median and tails improve)"))
    if csv:
        emit(rows)
    return out


def main():
    run()


if __name__ == "__main__":
    main()
