"""Figure 10: join pruning impact on probe-side scans where applied.

Paper: ~13% of queries at ratio 1.0 (empty build side), median >= 0.72,
probe-side reductions up to 99.99%.
"""

from __future__ import annotations

import numpy as np

from repro.core import expr as E
from repro.core.flow import JoinSpec, PruningPipeline, Query, TableScanSpec

from .common import dist_stats, emit, timeit
from .workload import sample_join_query, tables


def run(n: int = 60, seed: int = 7, csv: bool = True):
    rng = np.random.default_rng(seed)
    events, users = tables(seed)
    pipe = PruningPipeline()
    ratios = []
    for _ in range(n):
        if rng.random() < 0.13:
            # empty build side (e.g. a filter that matches nothing)
            q = Query(
                scans={
                    "users": TableScanSpec(users, E.col("age") > 200),
                    "events": TableScanSpec(events),
                },
                join=JoinSpec("users", "events", "id", "user_id"),
            )
        else:
            q = sample_join_query(rng, events, users)
            # isolate the JOIN stage: fig10 measures probe-side pruning
            # alone, so strip the (ts<->user_id correlated) probe filter
            # that would otherwise compound with it
            q.scans["events"] = TableScanSpec(events, E.true())
        rep = pipe.run(q)
        r = rep.per_scan["events"].get("join")
        if r and r.applied:
            ratios.append(r.ratio)
    a = np.asarray(ratios)
    us = timeit(lambda: pipe.run(sample_join_query(rng, events, users)))
    rows = [
        ("fig10_join_ratio", us, dist_stats(ratios) + " (paper median ~0.72)"),
        ("fig10_frac_full_prune", us,
         f"{float((a >= 1.0).mean()):.3f} (paper ~0.13)"),
    ]
    if csv:
        emit(rows)
    return a


def main():
    run()


if __name__ == "__main__":
    main()
