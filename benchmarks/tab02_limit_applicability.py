"""Table 2: LIMIT-pruning applicability breakdown.

Paper (overall): already-minimal 64.22%, unsupported shapes 31.28%,
pruned-to-1 3.85%, pruned-to->1 0.23%.  Shares depend on the production
query mix; we report our generator's shares next to the paper's.
"""

from __future__ import annotations

import numpy as np

from repro.core.flow import PruningPipeline
from repro.core.prune_limit import (ALREADY_MINIMAL, NO_FULLY_MATCHING,
                                    PRUNED_TO_0, PRUNED_TO_1, PRUNED_TO_N,
                                    UNSUPPORTED_SHAPE)

from .common import emit, timeit
from .workload import sample_limit_query, tables

PAPER_OVERALL = {
    ALREADY_MINIMAL: 0.6422,
    UNSUPPORTED_SHAPE: 0.3128,
    PRUNED_TO_1: 0.0385,
    PRUNED_TO_N: 0.0023,
}


def run(n: int = 200, seed: int = 3, csv: bool = True):
    rng = np.random.default_rng(seed)
    events, _ = tables(seed)
    pipe = PruningPipeline()
    counts: dict = {}
    for _ in range(n):
        q = sample_limit_query(rng, events)
        # a share of production LIMIT queries sit on shapes that block
        # pushdown (joins/aggregations) — Table 2's 'unsupported'
        if rng.random() < 0.25:
            q.group_by = ("region",)
        rep = pipe.run(q)
        lim = rep.per_scan["events"].get("limit")
        cat = lim.detail["category"] if lim else UNSUPPORTED_SHAPE
        counts[cat] = counts.get(cat, 0) + 1
    us = timeit(lambda: pipe.run(sample_limit_query(rng, events)))
    rows = []
    # The paper's 'unsupported shapes' row covers both shape-blocked
    # pushdown AND queries without fully-matching partitions (Sec. 4.4
    # "unsupported shape or without fully-matching partitions").
    merged = dict(counts)
    merged[UNSUPPORTED_SHAPE] = (merged.get(UNSUPPORTED_SHAPE, 0)
                                 + merged.pop(NO_FULLY_MATCHING, 0))
    # PRUNED_TO_0 (LIMIT 0 wipes, ~28% of the generator's LIMIT mix) is
    # its own category since ISSUE 3's honest-accounting fix; the paper's
    # table has no explicit row for it.
    for cat in (ALREADY_MINIMAL, UNSUPPORTED_SHAPE, PRUNED_TO_0,
                PRUNED_TO_1, PRUNED_TO_N):
        got = merged.get(cat, 0) / n
        paper = PAPER_OVERALL.get(cat)
        note = f"measured={got:.4f}" + (f" paper={paper:.4f}" if paper else "")
        rows.append((f"tab02_{cat}", us, note))
    if csv:
        emit(rows)
    return counts


def main():
    run()


if __name__ == "__main__":
    main()
