"""Shared synthetic workload for the paper-figure benchmarks.

Calibrated to the paper's published workload statistics (DESIGN.md §7):
  * predicate selectivity skews extremely high (Sec. 1/8.3: production
    queries are far more selective than TPC-H),
  * LIMIT k follows the Figure 6 distribution,
  * query-type mix follows Table 1 (2.60% LIMIT, 5.55% top-k, ...),
  * tables arrive clustered on ingestion time with correlated categorical
    columns (what makes min/max pruning effective in production).
"""

from __future__ import annotations


import numpy as np

from repro.core import expr as E
from repro.core.flow import JoinSpec, Query, TableScanSpec
from repro.data.generator import (make_events_table, make_users_table,
                                  sample_limit_k)
from repro.data.table import Table

_CACHE = {}


def tables(seed: int = 0, n_rows: int = 150_000, rows_pp: int = 750):
    key = (seed, n_rows, rows_pp)
    if key not in _CACHE:
        rng = np.random.default_rng(seed)
        events = make_events_table(rng, n_rows=n_rows, rows_per_partition=rows_pp,
                                   ts_clustering=0.995, user_clustering=0.995)
        users = make_users_table(rng, n_rows=max(n_rows // 10, 2000),
                                 rows_per_partition=rows_pp)
        _CACHE[key] = (events, users)
    return _CACHE[key]


def sample_filter_pred(rng: np.random.Generator, events: Table) -> E.Pred:
    """Production-style predicate mix, calibrated so the Figure 4 CDF
    lands near the paper's anchor points (~36% of queries pruning >=90%,
    ~27% pruning nothing)."""
    u = rng.random()
    ts_max = 10_000_000
    if u < 0.28:
        # recent-data scan: selectivity lognormal around ~1%
        frac = float(np.exp(rng.normal(np.log(0.01), 1.4)))
        frac = min(frac, 1.0)
        lo = ts_max * (1 - frac)
        return E.col("ts") >= lo
    if u < 0.42:
        # time window + categorical
        frac = float(np.exp(rng.normal(np.log(0.03), 1.0)))
        lo = ts_max * (1 - min(frac, 1.0))
        grp = rng.choice(["ok", "warn", "err", "crit"])
        return (E.col("ts") >= lo) & E.startswith(E.col("status"), str(grp))
    if u < 0.75:
        # categorical only (moderately selective, moderately clustered)
        grp = rng.choice(["ok", "warn", "err", "crit"])
        return E.like(E.col("status"), f"{grp}-%")
    # unselective predicate (the paper's ~27% of filter queries that
    # prune nothing)
    return E.col("score") >= float(rng.uniform(0.0, 0.2))


def tight_window_pred(rng: np.random.Generator) -> E.Pred:
    """The dominant big-table query: a tight recent-time window."""
    frac = float(np.exp(rng.normal(np.log(0.004), 1.0)))
    return E.col("ts") >= 10_000_000 * (1 - min(frac, 1.0))


def sample_topk_query(rng, events: Table, pred_prob: float = 0.5) -> Query:
    k = 0
    while k <= 0:
        k = sample_limit_k(rng)
    k = min(k, 200)
    pred = sample_filter_pred(rng, events) if rng.random() < pred_prob \
        else E.true()
    return Query(
        scans={"events": TableScanSpec(events, pred)},
        limit=int(k),
        order_by=("events", "num_sightings", True),
    )


def small_table(seed: int = 0) -> Table:
    """Dimension-table stand-in: the small tables most dashboard LIMIT
    queries actually hit (why Table 2 sees 64% 'already minimal')."""
    key = ("small", seed)
    if key not in _CACHE:
        rng = np.random.default_rng(seed + 99)
        _CACHE[key] = make_users_table(rng, n_rows=600, rows_per_partition=750)
    return _CACHE[key]


def sample_limit_query(rng, events: Table) -> Query:
    with_pred = rng.random() < (2.23 / 2.60)     # Table 1 split
    if rng.random() < 0.72:
        # dashboard-style LIMIT over a small dimension table
        tbl = small_table()
        pred = (E.col("age") >= int(rng.integers(20, 60))) if with_pred \
            else E.true()
        scans = {"events": TableScanSpec(tbl, pred)}
    else:
        pred = sample_filter_pred(rng, events) if with_pred else E.true()
        scans = {"events": TableScanSpec(events, pred)}
    return Query(
        scans=scans,
        limit=sample_limit_k(rng),
        offset=int(rng.integers(0, 10)) if rng.random() < 0.1 else 0,
    )


def sample_join_query(rng, events: Table, users: Table) -> Query:
    # selective build-side predicate on the correlated dimension attribute
    age_lo = int(rng.integers(65, 85))
    return Query(
        scans={
            "users": TableScanSpec(users, E.col("age") >= age_lo),
            "events": TableScanSpec(events, sample_filter_pred(rng, events)
                                    if rng.random() < 0.5 else E.true()),
        },
        join=JoinSpec("users", "events", "id", "user_id"),
    )
