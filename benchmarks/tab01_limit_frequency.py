"""Table 1: relative frequency of LIMIT-query types among SELECTs.

We sample a 10k-query population from the paper's published mix and
verify the classifier recovers it (pattern-matching on the Query struct,
the analogue of the paper's SQL-text matching).
"""

from __future__ import annotations

import numpy as np

from repro.core import expr as E
from repro.core.flow import Query, TableScanSpec

from .common import emit, timeit
from .workload import tables

PAPER = {
    "limit_no_pred": 0.0037,
    "limit_with_pred": 0.0223,
    "orderby_limit": 0.0447,
    "groupby_orderby_key": 0.0012,
    "groupby_orderby_agg": 0.0096,
}


def classify(q: Query) -> str:
    if q.limit is None:
        return "plain"
    if q.order_by is None:
        has_pred = any(not isinstance(s.pred, E.TruePred)
                       for s in q.scans.values())
        return "limit_with_pred" if has_pred else "limit_no_pred"
    if q.group_by:
        return "groupby_orderby_agg" if q.order_by_is_aggregate \
            else "groupby_orderby_key"
    return "orderby_limit"


def sample_population(rng, events, n: int):
    qs = []
    for _ in range(n):
        u = rng.random()
        acc = 0.0
        kind = "plain"
        for k, p in PAPER.items():
            acc += p
            if u < acc:
                kind = k
                break
        pred = (E.col("ts") >= 9_000_000) if "with_pred" in kind or \
            "orderby" in kind else E.true()
        if kind == "plain":
            qs.append(Query(scans={"events": TableScanSpec(events, pred)}))
        elif kind in ("limit_no_pred", "limit_with_pred"):
            pred = E.true() if kind == "limit_no_pred" else pred
            qs.append(Query(scans={"events": TableScanSpec(events, pred)},
                            limit=10))
        elif kind == "orderby_limit":
            qs.append(Query(scans={"events": TableScanSpec(events, pred)},
                            limit=10, order_by=("events", "num_sightings", True)))
        elif kind == "groupby_orderby_key":
            qs.append(Query(scans={"events": TableScanSpec(events, pred)},
                            limit=10, order_by=("events", "region", True),
                            group_by=("region",)))
        else:
            qs.append(Query(scans={"events": TableScanSpec(events, pred)},
                            limit=10, order_by=("events", "num_sightings", True),
                            group_by=("region",), order_by_is_aggregate=True))
    return qs


def run(n: int = 10_000, seed: int = 2, csv: bool = True):
    rng = np.random.default_rng(seed)
    events, _ = tables(seed, n_rows=20_000)
    qs = sample_population(rng, events, n)
    counts: dict = {}
    for q in qs:
        counts[classify(q)] = counts.get(classify(q), 0) + 1
    us = timeit(lambda: [classify(q) for q in qs[:1000]])
    rows = []
    for k, paper_p in PAPER.items():
        got = counts.get(k, 0) / n
        rows.append((f"tab01_{k}", us / 1000,
                     f"measured={got:.4f} paper={paper_p:.4f}"))
    total_limit = sum(v for k, v in counts.items() if k != "plain") / n
    rows.append(("tab01_total_limit_like", us / 1000,
                 f"measured={total_limit:.4f} paper=0.0815"))
    if csv:
        emit(rows)
    return counts


def main():
    run()


if __name__ == "__main__":
    main()
